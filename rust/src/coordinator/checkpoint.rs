//! Run-directory checkpointing: incremental JSONL result streaming and
//! resume for suite runs.
//!
//! A run directory holds:
//!   * `manifest.json`  — the matrix shape (task count, seeds, rt/at, task
//!     fingerprint); resume refuses a directory written for a different
//!     matrix.
//!   * `results.jsonl`  — one line per completed `(strategy, task, seed)`
//!     cell, appended *as cells finish* (not when the matrix completes), so
//!     a killed run loses at most the in-flight cells.
//!   * `memory_snapshot.<strategy>.json` — the skill-store warm-start
//!     snapshot taken at each strategy's run start; resume reloads it so
//!     warm-started retrieval is byte-identical to the uninterrupted run.
//!
//! Serialization is the full [`TaskResult`] — including the per-round trace
//! and the winning schedule — via the repo's own JSON layer (serde is not
//! vendored offline). f64 round-trips exactly (Rust's `Display` prints the
//! shortest representation that parses back to the same bits), which is what
//! makes resumed aggregates byte-identical to uninterrupted ones.

use std::collections::BTreeMap;
use std::io::{self, Write};
use std::path::{Path, PathBuf};

use super::loop_runner::{Branch, RoundRecord, TaskResult};
use crate::kir::schedule::{GroupSchedule, Layout, Precision, Schedule};
use crate::kir::transforms::MethodId;
use crate::memory::long_term::SkillObs;
use crate::util::json::{self, Json};
use crate::util::rng::label;

/// Identity of one cell in the (strategy × task × seed) matrix.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct CellKey {
    /// Strategy name the cell ran under.
    pub strategy: String,
    /// Task id of the cell.
    pub task_id: String,
    /// Run seed of the cell.
    pub seed: u64,
}

/// Matrix shape recorded at run start; resume validates against it. Shard
/// runs additionally record which slice of the matrix this directory owns,
/// so resume cannot silently mix shard assignments and `merge` can check
/// that its inputs partition one and the same matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct RunManifest {
    /// Number of tasks in the matrix.
    pub n_tasks: usize,
    /// Run seeds the matrix fans over.
    pub seeds: Vec<u64>,
    /// Relative promotion threshold the run used.
    pub rt: f64,
    /// Absolute promotion threshold the run used.
    pub at: f64,
    /// Order-sensitive fold of the task ids.
    pub fingerprint: u64,
    /// Total shard count this directory was written under (1 = unsharded).
    pub shards: usize,
    /// This directory's shard index (0 when unsharded).
    pub shard_index: usize,
    /// Cells per live memory-exchange epoch (0 = exchange disabled). Part
    /// of the experiment identity, not the shard assignment: cells of an
    /// exchange run retrieve against epoch-folded snapshots, so results
    /// from different epoch lengths may not be mixed by resume *or* merge.
    pub exchange_epoch: usize,
    /// Device preset the run priced against (`DeviceSpec::name`). Part of
    /// the experiment identity: the cost model and the skill-store
    /// partition observations land in both depend on it, so results from
    /// different devices may not be mixed by resume or merge. Pre-device
    /// manifests read as the legacy (A100-like) preset.
    pub device: String,
    /// Whether the exchange window schedule is the deterministic doubling
    /// one (see `scheduler::exchange_windows`). Part of the experiment
    /// identity like `exchange_epoch`: cells of an adaptive run retrieved
    /// against differently-cut epoch folds. Pre-elastic manifests read as
    /// fixed-length windows.
    pub exchange_adaptive: bool,
    /// Total lease-batch count this directory was written under (0 = the
    /// directory was not produced by elastic batch slicing). Placement,
    /// not identity — excluded from [`RunManifest::same_matrix`] exactly
    /// like the shard fields.
    pub lease_batches: usize,
    /// This directory's lease-batch index (meaningful only when
    /// `lease_batches > 0`).
    pub lease_batch: usize,
    /// Canonical render of the chaos config the run injected faults under
    /// (empty = no chaos). Part of the experiment identity: chaotic cells
    /// saw corrupted measurements and extra transient faults, so their
    /// results may not be mixed with a clean run's (or a differently-seeded
    /// chaotic run's) by resume or merge. Pre-chaos manifests read as
    /// chaos-free.
    pub chaos: String,
}

impl RunManifest {
    /// Order-sensitive fingerprint of a task-id list (resume and merge use
    /// it to detect a different matrix at equal shape).
    pub fn fingerprint_tasks(task_ids: &[&str]) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &id in task_ids {
            h = h.rotate_left(5) ^ label(id);
        }
        h
    }

    /// True when `other` describes the same (strategy-independent) cell
    /// matrix — shard *and* lease-batch fields excluded, since different
    /// slices of one run legitimately differ there (placement, not
    /// identity). The exchange epoch, the adaptive-window flag, and the
    /// device preset *are* included: an exchange run's cells saw
    /// epoch-folded memory cut on that exact schedule, and a run's cells
    /// were priced against (and recorded skills for) one device — neither
    /// is a slice of a differently-configured experiment. This is
    /// `merge`'s compatibility check.
    pub fn same_matrix(&self, other: &RunManifest) -> bool {
        self.same_matrix_modulo_device(other) && self.device == other.device
    }

    /// [`RunManifest::same_matrix`] minus the device check. This is the
    /// compatibility predicate for *heterogeneous-fleet* merges: shards of
    /// one experiment run on different presets share every identity field
    /// except the device, and their evidence stays separated by the skill
    /// store's per-device partitions rather than by a merge refusal. Resume
    /// does NOT use this — reopening a directory under a different preset
    /// is still a hard error (full manifest equality).
    pub fn same_matrix_modulo_device(&self, other: &RunManifest) -> bool {
        self.n_tasks == other.n_tasks
            && self.seeds == other.seeds
            && self.rt == other.rt
            && self.at == other.at
            && self.fingerprint == other.fingerprint
            && self.exchange_epoch == other.exchange_epoch
            && self.exchange_adaptive == other.exchange_adaptive
            && self.chaos == other.chaos
    }

    fn to_json(&self) -> Json {
        json::obj(vec![
            ("version", json::num(1.0)),
            ("n_tasks", json::num(self.n_tasks as f64)),
            (
                "seeds",
                json::arr(self.seeds.iter().map(|s| json::s(&s.to_string())).collect()),
            ),
            ("rt", json::num(self.rt)),
            ("at", json::num(self.at)),
            ("fingerprint", json::s(&self.fingerprint.to_string())),
            ("shards", json::num(self.shards as f64)),
            ("shard_index", json::num(self.shard_index as f64)),
            ("exchange_epoch", json::num(self.exchange_epoch as f64)),
            ("exchange_adaptive", Json::Bool(self.exchange_adaptive)),
            ("device", json::s(&self.device)),
            ("lease_batches", json::num(self.lease_batches as f64)),
            ("lease_batch", json::num(self.lease_batch as f64)),
            ("chaos", json::s(&self.chaos)),
        ])
    }

    fn from_json(j: &Json) -> Result<RunManifest, String> {
        let n_tasks = j
            .get("n_tasks")
            .and_then(|v| v.as_usize())
            .ok_or("manifest missing n_tasks")?;
        let seeds = j
            .get("seeds")
            .and_then(|v| v.as_arr())
            .ok_or("manifest missing seeds")?
            .iter()
            .map(|s| parse_u64(s, "seed"))
            .collect::<Result<Vec<_>, _>>()?;
        let rt = j.get("rt").and_then(|v| v.as_f64()).ok_or("manifest missing rt")?;
        let at = j.get("at").and_then(|v| v.as_f64()).ok_or("manifest missing at")?;
        let fingerprint = j
            .get("fingerprint")
            .map(|f| parse_u64(f, "fingerprint"))
            .transpose()?
            .unwrap_or(0);
        // Pre-sharding manifests carry no shard fields: they were written
        // by a single process, i.e. shard 0 of 1.
        let shards = j.get("shards").and_then(|v| v.as_usize()).unwrap_or(1);
        let shard_index = j.get("shard_index").and_then(|v| v.as_usize()).unwrap_or(0);
        // Pre-exchange manifests never ran with live memory exchange.
        let exchange_epoch = j.get("exchange_epoch").and_then(|v| v.as_usize()).unwrap_or(0);
        // Pre-elastic manifests used fixed-length exchange windows and were
        // never written by batch slicing.
        let exchange_adaptive = matches!(j.get("exchange_adaptive"), Some(Json::Bool(true)));
        let lease_batches = j.get("lease_batches").and_then(|v| v.as_usize()).unwrap_or(0);
        let lease_batch = j.get("lease_batch").and_then(|v| v.as_usize()).unwrap_or(0);
        // Pre-device manifests were all priced against the default preset.
        let device = j
            .get("device")
            .and_then(|v| v.as_str())
            .unwrap_or(crate::memory::long_term::skill_store::LEGACY_DEVICE)
            .to_string();
        // Pre-chaos manifests never injected environment faults.
        let chaos = j
            .get("chaos")
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string();
        Ok(RunManifest {
            n_tasks,
            seeds,
            rt,
            at,
            fingerprint,
            shards,
            shard_index,
            exchange_epoch,
            device,
            exchange_adaptive,
            lease_batches,
            lease_batch,
            chaos,
        })
    }
}

/// Filesystem slug for a strategy name (lowercased, non-alphanumerics to
/// `_`) — shared by the warm-start snapshot files and the memory-exchange
/// directory layout.
pub fn strategy_slug(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
        .collect()
}

/// Handle on a run directory.
#[derive(Debug, Clone)]
pub struct RunDir {
    root: PathBuf,
}

impl RunDir {
    /// Open (creating if needed) a run directory.
    pub fn open<P: AsRef<Path>>(root: P) -> io::Result<RunDir> {
        std::fs::create_dir_all(root.as_ref())?;
        Ok(RunDir {
            root: root.as_ref().to_path_buf(),
        })
    }

    /// The directory this handle points at.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Path of the JSONL cell checkpoint.
    pub fn results_path(&self) -> PathBuf {
        self.root.join("results.jsonl")
    }

    /// Path of the matrix-shape manifest.
    pub fn manifest_path(&self) -> PathBuf {
        self.root.join("manifest.json")
    }

    /// Per-run-dir skill store: the fold of every checkpointed cell's
    /// observations. The scheduler rebuilds it from the checkpoint on open
    /// and saves it once per dispatch round, which is what lets `merge`
    /// combine shards' stores without re-running anything (`merge` treats
    /// the checkpointed cells as authoritative if this file ever lags).
    pub fn skills_path(&self) -> PathBuf {
        self.root.join("skills.json")
    }

    /// Skill-store warm-start snapshot for one strategy. Per-strategy files:
    /// in a matrix run, later strategies legitimately start from a live
    /// store that already includes earlier strategies' merges, so each
    /// strategy's snapshot must be captured (and resumed from) separately.
    pub fn memory_snapshot_path(&self, strategy: &str) -> PathBuf {
        self.root
            .join(format!("memory_snapshot.{}.json", strategy_slug(strategy)))
    }

    /// True once at least one result line has been streamed.
    pub fn has_results(&self) -> bool {
        std::fs::metadata(self.results_path())
            .map(|m| m.len() > 0)
            .unwrap_or(false)
    }

    /// Name of the completion marker file inside a run directory. Exposed
    /// so observers (the streaming merge) can probe for it without
    /// constructing a `RunDir` — `RunDir::open` creates the directory,
    /// which a read-only probe of a possibly-missing path must not do.
    pub const COMPLETE_MARKER: &'static str = "complete";

    /// Path of the completion marker (see [`RunDir::mark_complete`]).
    pub fn complete_path(&self) -> PathBuf {
        self.root.join(Self::COMPLETE_MARKER)
    }

    /// Write the completion marker: the producing process finished its whole
    /// slice of the matrix and will append nothing more. `merge --watch` and
    /// the shard launcher use it to know when tail-following can stop;
    /// resuming a marked directory is still legal (resume re-validates
    /// against the manifest, not the marker).
    pub fn mark_complete(&self) -> io::Result<()> {
        std::fs::write(self.complete_path(), "complete\n")
    }

    /// True once [`RunDir::mark_complete`] has run.
    pub fn is_complete(&self) -> bool {
        self.complete_path().exists()
    }

    /// Write the matrix-shape manifest. Atomic (tmp + rename): a streaming
    /// merge may read the manifest the moment it appears, so a torn
    /// half-written file must never be observable.
    pub fn write_manifest(&self, m: &RunManifest) -> io::Result<()> {
        let path = self.manifest_path();
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, format!("{}\n", m.to_json()))?;
        std::fs::rename(&tmp, &path)
    }

    /// Read the manifest; `None` when the directory has none yet.
    pub fn read_manifest(&self) -> Result<Option<RunManifest>, String> {
        let path = self.manifest_path();
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path).map_err(|e| format!("reading {path:?}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| format!("parsing {path:?}: {e}"))?;
        RunManifest::from_json(&j).map(Some)
    }

    /// Append one completed cell to `results.jsonl` and flush. One line per
    /// call; a crash can only tear the final line, which `load` tolerates.
    pub fn append(&self, key: &CellKey, r: &TaskResult) -> io::Result<()> {
        let path = self.results_path();
        // Heal a torn tail first: a hard kill can leave a partial record
        // with no trailing newline, and appending straight after it would
        // glue the new record onto the fragment — corrupting a *complete*
        // cell, not just the torn one. A lone newline isolates the fragment
        // so `load`/`load_all` skip exactly the torn line and nothing else.
        let needs_newline = match std::fs::File::open(&path) {
            Ok(mut f) => {
                use std::io::{Read, Seek, SeekFrom};
                let len = f.metadata()?.len();
                if len == 0 {
                    false
                } else {
                    f.seek(SeekFrom::End(-1))?;
                    let mut last = [0u8; 1];
                    f.read_exact(&mut last)?;
                    last[0] != b'\n'
                }
            }
            Err(_) => false, // no file yet
        };
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&path)?;
        if needs_newline {
            f.write_all(b"\n")?;
        }
        f.write_all(format!("{}\n", result_to_json(key, r)).as_bytes())?;
        f.flush()
    }

    /// Load every parseable cell line, duplicates included, in file order.
    /// Unparseable lines (torn tail of a killed run) are skipped with a
    /// warning. `merge` uses this directly so it can *see* duplicate keys
    /// and decide between deduplication and a loud conflict error.
    pub fn load_all(&self) -> io::Result<Vec<(CellKey, TaskResult)>> {
        let path = self.results_path();
        let mut out = Vec::new();
        if !path.exists() {
            return Ok(out);
        }
        let text = std::fs::read_to_string(&path)?;
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let parsed = Json::parse(line)
                .map_err(|e| e.to_string())
                .and_then(|j| result_from_json(&j));
            match parsed {
                Ok(cell) => out.push(cell),
                Err(e) => {
                    crate::log_warn!(
                        "checkpoint {}:{}: skipping unparseable line ({e})",
                        path.display(),
                        lineno + 1
                    );
                }
            }
        }
        Ok(out)
    }

    /// Load all completed cells. Unparseable lines (torn tail of a killed
    /// run) are skipped with a warning; later duplicates of a key win.
    pub fn load(&self) -> io::Result<BTreeMap<CellKey, TaskResult>> {
        Ok(self.load_all()?.into_iter().collect())
    }
}

// ------------------------------------------------------------------------
// TaskResult <-> JSON
// ------------------------------------------------------------------------

fn precision_name(p: Precision) -> &'static str {
    match p {
        Precision::F32 => "f32",
        Precision::Tf32 => "tf32",
        Precision::Bf16Acc32 => "bf16acc32",
    }
}

fn precision_from(s: &str) -> Result<Precision, String> {
    match s {
        "f32" => Ok(Precision::F32),
        "tf32" => Ok(Precision::Tf32),
        "bf16acc32" => Ok(Precision::Bf16Acc32),
        other => Err(format!("unknown precision {other:?}")),
    }
}

fn layout_name(l: Layout) -> &'static str {
    match l {
        Layout::Coalesced => "coalesced",
        Layout::Strided => "strided",
        Layout::Tiled => "tiled",
    }
}

fn layout_from(s: &str) -> Result<Layout, String> {
    match s {
        "coalesced" => Ok(Layout::Coalesced),
        "strided" => Ok(Layout::Strided),
        "tiled" => Ok(Layout::Tiled),
        other => Err(format!("unknown layout {other:?}")),
    }
}

fn group_to_json(g: &GroupSchedule) -> Json {
    json::obj(vec![
        ("tile_m", json::num(g.tile_m as f64)),
        ("tile_n", json::num(g.tile_n as f64)),
        ("tile_k", json::num(g.tile_k as f64)),
        ("staging", Json::Bool(g.staging)),
        ("vector_width", json::num(g.vector_width as f64)),
        ("mxu", Json::Bool(g.mxu)),
        ("precision", json::s(precision_name(g.precision))),
        ("double_buffer", Json::Bool(g.double_buffer)),
        ("layout", json::s(layout_name(g.layout))),
        ("unroll", json::num(g.unroll as f64)),
        ("block_threads", json::num(g.block_threads as f64)),
        ("smem_padding", Json::Bool(g.smem_padding)),
        ("split_k", json::num(g.split_k as f64)),
    ])
}

fn get_f(j: &Json, k: &str) -> Result<f64, String> {
    j.get(k)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| format!("missing number {k}"))
}

fn get_b(j: &Json, k: &str) -> Result<bool, String> {
    match j.get(k) {
        Some(Json::Bool(b)) => Ok(*b),
        _ => Err(format!("missing bool {k}")),
    }
}

fn get_s<'a>(j: &'a Json, k: &str) -> Result<&'a str, String> {
    j.get(k)
        .and_then(|v| v.as_str())
        .ok_or_else(|| format!("missing string {k}"))
}

fn get_arr<'a>(j: &'a Json, k: &str) -> Result<&'a [Json], String> {
    j.get(k)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| format!("missing array {k}"))
}

/// Optional float: absent or null reads as None.
fn get_opt_f(j: &Json, k: &str) -> Option<f64> {
    j.get(k).and_then(|v| v.as_f64())
}

fn parse_u64(j: &Json, what: &str) -> Result<u64, String> {
    match j {
        Json::Str(s) => s.parse::<u64>().map_err(|e| format!("bad {what}: {e}")),
        Json::Num(n) => Ok(*n as u64),
        _ => Err(format!("bad {what}")),
    }
}

fn group_from_json(j: &Json) -> Result<GroupSchedule, String> {
    Ok(GroupSchedule {
        tile_m: get_f(j, "tile_m")? as u64,
        tile_n: get_f(j, "tile_n")? as u64,
        tile_k: get_f(j, "tile_k")? as u64,
        staging: get_b(j, "staging")?,
        vector_width: get_f(j, "vector_width")? as u8,
        mxu: get_b(j, "mxu")?,
        precision: precision_from(get_s(j, "precision")?)?,
        double_buffer: get_b(j, "double_buffer")?,
        layout: layout_from(get_s(j, "layout")?)?,
        unroll: get_f(j, "unroll")? as u8,
        block_threads: get_f(j, "block_threads")? as u32,
        smem_padding: get_b(j, "smem_padding")?,
        split_k: get_f(j, "split_k")? as u32,
    })
}

/// Serialize a full schedule (groups + per-group config) for checkpoints.
pub fn schedule_to_json(s: &Schedule) -> Json {
    json::obj(vec![
        (
            "groups",
            json::arr(
                s.groups
                    .iter()
                    .map(|g| json::arr(g.iter().map(|&op| json::num(op as f64)).collect()))
                    .collect(),
            ),
        ),
        ("cfg", json::arr(s.cfg.iter().map(group_to_json).collect())),
        ("specialized", Json::Bool(s.specialized)),
    ])
}

/// Parse a schedule serialized by [`schedule_to_json`].
pub fn schedule_from_json(j: &Json) -> Result<Schedule, String> {
    let mut groups = Vec::new();
    for g in get_arr(j, "groups")? {
        let ops = g.as_arr().ok_or("group is not an array")?;
        let mut ids = Vec::new();
        for op in ops {
            ids.push(op.as_usize().ok_or("bad op id")?);
        }
        groups.push(ids);
    }
    let mut cfg = Vec::new();
    for c in get_arr(j, "cfg")? {
        cfg.push(group_from_json(c)?);
    }
    Ok(Schedule {
        groups,
        cfg,
        specialized: get_b(j, "specialized")?,
    })
}

fn branch_to_json(b: &Branch) -> Json {
    match b {
        Branch::Optimize(m) => {
            json::obj(vec![("t", json::s("optimize")), ("m", json::s(m.name()))])
        }
        Branch::Repair(fix) => {
            json::obj(vec![("t", json::s("repair")), ("fix", json::num(*fix as f64))])
        }
        Branch::Revert => json::obj(vec![("t", json::s("revert"))]),
        Branch::Converged => json::obj(vec![("t", json::s("converged"))]),
    }
}

fn branch_from_json(j: &Json) -> Result<Branch, String> {
    match get_s(j, "t")? {
        "optimize" => {
            let name = get_s(j, "m")?;
            let m = MethodId::from_name(name).ok_or_else(|| format!("unknown method {name:?}"))?;
            Ok(Branch::Optimize(m))
        }
        "repair" => Ok(Branch::Repair(get_f(j, "fix")? as u8)),
        "revert" => Ok(Branch::Revert),
        "converged" => Ok(Branch::Converged),
        other => Err(format!("unknown branch {other:?}")),
    }
}

fn round_to_json(r: &RoundRecord) -> Json {
    json::obj(vec![
        ("round", json::num(r.round as f64)),
        ("branch", branch_to_json(&r.branch)),
        ("compiled", Json::Bool(r.compiled)),
        ("correct", Json::Bool(r.correct)),
        ("speedup", r.speedup.map(json::num).unwrap_or(Json::Null)),
        ("version", json::num(r.version as f64)),
    ])
}

fn round_from_json(j: &Json) -> Result<RoundRecord, String> {
    Ok(RoundRecord {
        round: get_f(j, "round")? as u32,
        branch: branch_from_json(j.get("branch").ok_or("missing branch")?)?,
        compiled: get_b(j, "compiled")?,
        correct: get_b(j, "correct")?,
        speedup: get_opt_f(j, "speedup"),
        version: get_f(j, "version")? as u32,
    })
}

fn obs_to_json(o: &SkillObs) -> Json {
    json::obj(vec![
        ("case", json::s(&o.case_id)),
        ("method", json::s(o.method.name())),
        ("gain", o.gain.map(json::num).unwrap_or(Json::Null)),
        ("device", json::s(&o.device)),
    ])
}

fn obs_from_json(j: &Json) -> Result<SkillObs, String> {
    let name = get_s(j, "method")?;
    Ok(SkillObs {
        case_id: get_s(j, "case")?.to_string(),
        method: MethodId::from_name(name).ok_or_else(|| format!("unknown method {name:?}"))?,
        gain: get_opt_f(j, "gain"),
        // Pre-v3 checkpoints carried no device field; every pre-v3 run
        // used the default (A100-like) preset.
        device: j
            .get("device")
            .and_then(|v| v.as_str())
            .unwrap_or(crate::memory::long_term::skill_store::LEGACY_DEVICE)
            .to_string(),
    })
}

/// Serialize one completed cell (key + full result) to a JSONL value.
pub fn result_to_json(key: &CellKey, r: &TaskResult) -> Json {
    json::obj(vec![
        ("strategy", json::s(&key.strategy)),
        ("task_id", json::s(&r.task_id)),
        ("seed", json::s(&key.seed.to_string())),
        ("level", json::num(r.level as f64)),
        ("success", Json::Bool(r.success)),
        ("best_speedup", json::num(r.best_speedup)),
        ("seed_speedup", r.seed_speedup.map(json::num).unwrap_or(Json::Null)),
        ("rounds_used", json::num(r.rounds_used as f64)),
        ("rounds", json::arr(r.rounds.iter().map(round_to_json).collect())),
        ("promotions", json::num(r.promotions as f64)),
        ("repair_attempts", json::num(r.repair_attempts as f64)),
        (
            "longest_repair_chain",
            json::num(r.longest_repair_chain as f64),
        ),
        ("best_sched", schedule_to_json(&r.best_sched)),
        ("skill_obs", json::arr(r.skill_obs.iter().map(obs_to_json).collect())),
    ])
}

/// Map a strategy name from disk back to its `&'static str`. Known roster
/// names are interned; unknown ones are leaked once per distinct name
/// (memoized process-wide, so loading a large checkpoint leaks at most one
/// allocation per strategy, not one per line).
pub fn intern_strategy_name(name: &str) -> &'static str {
    use std::sync::{Mutex, OnceLock};
    static ROSTER: OnceLock<Vec<&'static str>> = OnceLock::new();
    static EXTRA: OnceLock<Mutex<Vec<&'static str>>> = OnceLock::new();
    let roster = ROSTER.get_or_init(|| {
        crate::baselines::table1_roster()
            .into_iter()
            .chain(crate::baselines::table2_roster())
            .map(|s| s.name)
            .collect()
    });
    if let Some(&n) = roster.iter().find(|&&n| n == name) {
        return n;
    }
    // A poisoned lock only means another thread panicked mid-push; the
    // Vec is append-only and stays valid, so recover the guard instead of
    // propagating the panic into every checkpoint loader on the process.
    let mut extra = EXTRA
        .get_or_init(|| Mutex::new(Vec::new()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner());
    if let Some(&n) = extra.iter().find(|&&n| n == name) {
        return n;
    }
    let leaked: &'static str = Box::leak(name.to_string().into_boxed_str());
    extra.push(leaked);
    leaked
}

/// Deserialize one JSONL line back into its key + result.
pub fn result_from_json(j: &Json) -> Result<(CellKey, TaskResult), String> {
    let strategy = get_s(j, "strategy")?.to_string();
    let task_id = get_s(j, "task_id")?.to_string();
    let seed = parse_u64(j.get("seed").ok_or("missing seed")?, "seed")?;
    let mut rounds = Vec::new();
    for r in get_arr(j, "rounds")? {
        rounds.push(round_from_json(r)?);
    }
    let mut skill_obs = Vec::new();
    for o in get_arr(j, "skill_obs")? {
        skill_obs.push(obs_from_json(o)?);
    }
    let result = TaskResult {
        task_id: task_id.clone(),
        level: get_f(j, "level")? as u8,
        strategy: intern_strategy_name(&strategy),
        success: get_b(j, "success")?,
        best_speedup: get_f(j, "best_speedup")?,
        seed_speedup: get_opt_f(j, "seed_speedup"),
        rounds_used: get_f(j, "rounds_used")? as u32,
        rounds,
        promotions: get_f(j, "promotions")? as u32,
        repair_attempts: get_f(j, "repair_attempts")? as usize,
        longest_repair_chain: get_f(j, "longest_repair_chain")? as usize,
        best_sched: schedule_from_json(j.get("best_sched").ok_or("missing best_sched")?)?,
        skill_obs,
    };
    Ok((
        CellKey {
            strategy,
            task_id,
            seed,
        },
        result,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::bench_suite;
    use crate::coordinator::loop_runner::{run_task, LoopConfig};

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ks-ckpt-{tag}-{}", std::process::id()))
    }

    fn real_result() -> TaskResult {
        let tasks = bench_suite::level_suite(42, 1);
        run_task(&tasks[0], &baselines::kernelskill(), &LoopConfig::default())
    }

    #[test]
    fn result_roundtrip_is_exact() {
        let r = real_result();
        let key = CellKey {
            strategy: "KernelSkill".to_string(),
            task_id: r.task_id.clone(),
            seed: 7,
        };
        let line = result_to_json(&key, &r).to_string();
        let (k2, r2) = result_from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(key, k2);
        assert_eq!(r.task_id, r2.task_id);
        assert_eq!(r.level, r2.level);
        assert_eq!(r.strategy, r2.strategy);
        assert_eq!(r.success, r2.success);
        assert_eq!(r.best_speedup, r2.best_speedup, "f64 must round-trip exactly");
        assert_eq!(r.seed_speedup, r2.seed_speedup);
        assert_eq!(r.rounds_used, r2.rounds_used);
        assert_eq!(r.rounds, r2.rounds);
        assert_eq!(r.promotions, r2.promotions);
        assert_eq!(r.repair_attempts, r2.repair_attempts);
        assert_eq!(r.longest_repair_chain, r2.longest_repair_chain);
        assert_eq!(r.best_sched, r2.best_sched);
        assert_eq!(r.skill_obs, r2.skill_obs);
    }

    #[test]
    fn append_load_and_torn_tail() {
        let dir = tmp_dir("torn");
        let _ = std::fs::remove_dir_all(&dir);
        let rd = RunDir::open(&dir).unwrap();
        let r = real_result();
        let k1 = CellKey {
            strategy: "KernelSkill".to_string(),
            task_id: r.task_id.clone(),
            seed: 0,
        };
        let k2 = CellKey {
            seed: 1,
            ..k1.clone()
        };
        rd.append(&k1, &r).unwrap();
        rd.append(&k2, &r).unwrap();
        // Simulate a crash mid-write: torn, unparseable final line.
        {
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(rd.results_path())
                .unwrap();
            f.write_all(b"{\"strategy\":\"KernelSk").unwrap();
        }
        let loaded = rd.load().unwrap();
        assert_eq!(loaded.len(), 2);
        assert!(loaded.contains_key(&k1) && loaded.contains_key(&k2));
        assert_eq!(loaded[&k1].best_speedup, r.best_speedup);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn append_after_torn_tail_does_not_glue_records() {
        // A record appended after a hard kill (torn line, no trailing
        // newline) must not be swallowed by the fragment: resume-then-merge
        // depends on every *complete* cell surviving on disk.
        let dir = tmp_dir("heal");
        let _ = std::fs::remove_dir_all(&dir);
        let rd = RunDir::open(&dir).unwrap();
        let r = real_result();
        let k1 = CellKey {
            strategy: "KernelSkill".to_string(),
            task_id: r.task_id.clone(),
            seed: 0,
        };
        let k2 = CellKey {
            seed: 1,
            ..k1.clone()
        };
        rd.append(&k1, &r).unwrap();
        {
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(rd.results_path())
                .unwrap();
            f.write_all(b"{\"strategy\":\"KernelSk").unwrap();
        }
        rd.append(&k2, &r).unwrap();
        let loaded = rd.load().unwrap();
        assert_eq!(loaded.len(), 2, "the post-tear append must survive");
        assert!(loaded.contains_key(&k1) && loaded.contains_key(&k2));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_roundtrip_and_missing() {
        let dir = tmp_dir("manifest");
        let _ = std::fs::remove_dir_all(&dir);
        let rd = RunDir::open(&dir).unwrap();
        assert!(rd.read_manifest().unwrap().is_none());
        let m = RunManifest {
            n_tasks: 8,
            seeds: vec![0, 1, 2],
            rt: 0.3,
            at: 0.3,
            fingerprint: RunManifest::fingerprint_tasks(&["a", "b"]),
            shards: 3,
            shard_index: 2,
            exchange_epoch: 4,
            device: "tpu-like".to_string(),
            exchange_adaptive: true,
            lease_batches: 6,
            lease_batch: 5,
            chaos: "tc=0.3,drop=0,sigma=0.2,bias=0,seed=7".to_string(),
        };
        rd.write_manifest(&m).unwrap();
        assert_eq!(rd.read_manifest().unwrap(), Some(m));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_without_shard_fields_reads_as_unsharded() {
        let dir = tmp_dir("manifest-v1");
        let _ = std::fs::remove_dir_all(&dir);
        let rd = RunDir::open(&dir).unwrap();
        std::fs::write(
            rd.manifest_path(),
            r#"{"version":1,"n_tasks":4,"seeds":["0"],"rt":0.3,"at":0.3,"fingerprint":"7"}"#,
        )
        .unwrap();
        let m = rd.read_manifest().unwrap().unwrap();
        assert_eq!(m.shards, 1);
        assert_eq!(m.shard_index, 0);
        assert_eq!(m.exchange_epoch, 0, "pre-exchange manifests read as exchange-off");
        assert_eq!(m.device, "a100-like", "pre-device manifests read as the legacy preset");
        assert!(!m.exchange_adaptive, "pre-elastic manifests read as fixed windows");
        assert_eq!((m.lease_batches, m.lease_batch), (0, 0), "and as non-batch-sliced");
        assert_eq!(m.chaos, "", "pre-chaos manifests read as chaos-free");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn same_matrix_ignores_shard_fields_only() {
        let base = RunManifest {
            n_tasks: 4,
            seeds: vec![0, 1],
            rt: 0.3,
            at: 0.3,
            fingerprint: 99,
            shards: 1,
            shard_index: 0,
            exchange_epoch: 0,
            device: "a100-like".to_string(),
            exchange_adaptive: false,
            lease_batches: 0,
            lease_batch: 0,
            chaos: String::new(),
        };
        let mut other_shard = base.clone();
        other_shard.shards = 4;
        other_shard.shard_index = 3;
        assert!(base.same_matrix(&other_shard));
        // Lease-batch fields are placement too: a batch-sliced dir and a
        // round-robin shard of the same matrix merge together.
        let mut other_batch = base.clone();
        other_batch.lease_batches = 5;
        other_batch.lease_batch = 4;
        assert!(base.same_matrix(&other_batch));
        let mut other_matrix = base.clone();
        other_matrix.seeds = vec![0];
        assert!(!base.same_matrix(&other_matrix));
        // A different exchange-epoch length is a different experiment: its
        // cells retrieved against differently-folded memory.
        let mut other_epoch = base.clone();
        other_epoch.exchange_epoch = 8;
        assert!(!base.same_matrix(&other_epoch));
        // A different window *schedule* at the same epoch length is too.
        let mut other_schedule = base.clone();
        other_schedule.exchange_epoch = 8;
        other_schedule.exchange_adaptive = true;
        assert!(!other_epoch.same_matrix(&other_schedule));
        // So is a different device preset: its cells were priced against
        // different hardware and recorded skills in a different partition.
        let mut other_device = base.clone();
        other_device.device = "tpu-like".to_string();
        assert!(!base.same_matrix(&other_device));
        // ...but modulo-device (the heterogeneous-fleet merge predicate) a
        // device difference is the ONE permitted identity delta.
        assert!(base.same_matrix_modulo_device(&other_device));
        // A chaos config is identity under both predicates: chaotic cells
        // saw corrupted measurements no clean run produced.
        let mut other_chaos = base.clone();
        other_chaos.chaos = "tc=0.3,drop=0,sigma=0,bias=0,seed=1".to_string();
        assert!(!base.same_matrix(&other_chaos));
        assert!(!base.same_matrix_modulo_device(&other_chaos));
    }

    #[test]
    fn complete_marker_roundtrip() {
        let dir = tmp_dir("complete");
        let _ = std::fs::remove_dir_all(&dir);
        let rd = RunDir::open(&dir).unwrap();
        assert!(!rd.is_complete());
        rd.mark_complete().unwrap();
        assert!(rd.is_complete());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_all_keeps_duplicates_load_dedupes() {
        let dir = tmp_dir("dups");
        let _ = std::fs::remove_dir_all(&dir);
        let rd = RunDir::open(&dir).unwrap();
        let r = real_result();
        let k = CellKey {
            strategy: "KernelSkill".to_string(),
            task_id: r.task_id.clone(),
            seed: 0,
        };
        rd.append(&k, &r).unwrap();
        rd.append(&k, &r).unwrap();
        assert_eq!(rd.load_all().unwrap().len(), 2);
        assert_eq!(rd.load().unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_is_order_sensitive() {
        let a = RunManifest::fingerprint_tasks(&["x", "y"]);
        let b = RunManifest::fingerprint_tasks(&["y", "x"]);
        assert_ne!(a, b);
    }
}
