//! Kernel-optimization-as-a-service: the `serve` daemon and its client.
//!
//! `serve` turns the one-matrix-per-invocation batch pipeline into a
//! long-lived service: clients submit typed [`JobSpec`]s over a
//! newline-framed JSON protocol on localhost TCP (hand-rolled, zero
//! deps), the daemon queues them **durably** as per-job manifests under a
//! `--service-dir`, runs them one at a time as supervised child
//! processes, and streams progress events to watchers.
//!
//! Durability reuses the two substrates the repo already trusts:
//!
//! - every job is a directory `jobs/job-NNNNNN/` holding an atomically
//!   published manifest (`job.json`), the canonical spec
//!   (`job-spec.json`), and the job's own run dir — so crash recovery is
//!   re-scan + `--resume`, exactly like a shard child;
//! - scheduling goes through the PR-7 lease board
//!   ([`read_lease_board`]/[`claim_next_batch`]/[`expire_lease`]) over a
//!   [`LocalFs`] transport rooted at the service dir, with job *N* as
//!   batch *N−1* — claims are first-publish-wins, heartbeats are
//!   progress counters, and a daemon SIGKILL leaves an `.expired`
//!   audit marker when the restarted daemon re-dispatches the job.
//!
//! Multi-tenancy: when `serve` is given a base `--memory-dir`, each job
//! folds into a private copy-on-write overlay
//! ([`crate::memory::long_term::create_overlay`]) over the shared
//! segmented base — never into the base itself. Admission control is a
//! bounded queue: a submit over capacity is rejected with an explicit
//! `backpressure` reply, never silently dropped.
//!
//! Determinism contract (invariants 18–19, `docs/memory-formats.md`): a
//! job run through the service produces a report and folded skill store
//! byte-identical to the equivalent direct invocation, including after
//! the daemon is killed and restarted mid-job.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write as _};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::json::{self, Json};

use super::protocol::{response_err, response_ok, JobSpec, JobState, Request};
use super::transport::{
    claim_next_batch, expire_lease, read_lease_board, Lease, LocalFs, RunDirTransport,
};

/// Version of the per-job `job.json` manifest this daemon writes and the
/// only version it accepts (skewed manifests are refused loudly at scan).
pub const JOB_MANIFEST_VERSION: u64 = 1;

/// File under the service dir advertising the daemon's TCP address
/// (`127.0.0.1:<port>\n`), rewritten atomically at every startup.
pub const ENDPOINT_FILE: &str = "endpoint";

/// Directory under the service dir holding one subdirectory per job.
pub const JOBS_DIR: &str = "jobs";

/// Worker id the daemon claims leases under.
const SCHEDULER_ID: &str = "serve";

/// How long a client keeps retrying to reach a daemon that is still
/// coming up (endpoint file absent or connection refused).
const CONNECT_ATTEMPTS: usize = 50;
const CONNECT_RETRY_MS: u64 = 100;

/// Configuration for one `serve` daemon.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// The durable service directory (queue state, job dirs, lease board).
    pub service_dir: PathBuf,
    /// Binary to spawn for each job (normally `current_exe`).
    pub program: PathBuf,
    /// Shared segmented skill-store base; each job gets a copy-on-write
    /// overlay over it. `None` = jobs run memoryless, exactly like a
    /// direct invocation without `--memory-dir`.
    pub base_memory: Option<PathBuf>,
    /// Bounded-queue admission limit: max jobs queued + running before
    /// submits are rejected with backpressure.
    pub queue_capacity: usize,
    /// Scheduler/watcher poll cadence.
    pub poll_ms: u64,
    /// Crash-restart budget per job (the launcher's default).
    pub max_restarts: usize,
    /// TCP port to bind on 127.0.0.1; 0 = ephemeral (the address is
    /// advertised via the endpoint file either way).
    pub port: u16,
}

impl ServiceConfig {
    /// A config with the launcher-matching defaults.
    pub fn new(service_dir: PathBuf, program: PathBuf) -> ServiceConfig {
        ServiceConfig {
            service_dir,
            program,
            base_memory: None,
            queue_capacity: 16,
            poll_ms: 50,
            max_restarts: 2,
            port: 0,
        }
    }
}

/// One job's durable record: the `job.json` manifest plus its spec.
#[derive(Debug, Clone)]
struct JobEntry {
    /// `job-NNNNNN`; job number N is lease-board batch N−1.
    id: String,
    /// `<service-dir>/jobs/<id>`.
    dir: PathBuf,
    spec: JobSpec,
    state: JobState,
    /// Wall-clock budget in ms from job start; past it the job is killed
    /// and marked failed.
    deadline_ms: Option<u64>,
    error: Option<String>,
    restarts: usize,
    /// Pid of the job's child while running — the restarted daemon uses
    /// it to put down an orphan left by a SIGKILLed predecessor before
    /// re-dispatching (two writers on one run dir would race).
    pid: Option<u32>,
    /// In-memory only: a client asked to cancel the running job.
    cancel_requested: bool,
}

impl JobEntry {
    fn manifest_path(&self) -> PathBuf {
        self.dir.join("job.json")
    }

    fn spec_path(&self) -> PathBuf {
        self.dir.join("job-spec.json")
    }

    fn run_dir(&self) -> PathBuf {
        self.dir.join("run")
    }

    fn overlay_dir(&self) -> PathBuf {
        self.dir.join("memory")
    }

    /// Newline count of the job's checkpoint — the watcher's progress
    /// metric.
    fn cells(&self) -> u64 {
        match std::fs::read(self.run_dir().join("results.jsonl")) {
            Ok(bytes) => bytes.iter().filter(|b| **b == b'\n').count() as u64,
            Err(_) => 0,
        }
    }

    /// Byte length of the checkpoint — the lease heartbeat counter (the
    /// same progress-not-wall-clock liveness contract elastic fleets use).
    fn progress(&self) -> u64 {
        std::fs::metadata(self.run_dir().join("results.jsonl"))
            .map(|m| m.len())
            .unwrap_or(0)
    }

    fn to_manifest_json(&self) -> Json {
        let mut pairs = vec![
            ("id", json::s(&self.id)),
            ("restarts", json::num(self.restarts as f64)),
            ("state", json::s(self.state.as_str())),
            ("version", json::num(JOB_MANIFEST_VERSION as f64)),
        ];
        if let Some(d) = self.deadline_ms {
            pairs.push(("deadline_ms", json::s(&d.to_string())));
        }
        if let Some(e) = &self.error {
            pairs.push(("error", json::s(e)));
        }
        if let Some(p) = self.pid {
            pairs.push(("pid", json::num(p as f64)));
        }
        json::obj(pairs)
    }

    /// Atomically publish `job.json` (staging file + rename).
    fn save_manifest(&self) -> Result<(), String> {
        let path = self.manifest_path();
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, format!("{}\n", self.to_manifest_json()))
            .map_err(|e| format!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path).map_err(|e| format!("publishing {}: {e}", path.display()))
    }

    /// Strict manifest + spec load. Unknown fields, a skewed version, or
    /// an id that disagrees with the directory name are loud errors: a
    /// daemon must never half-understand a job it is about to re-run.
    fn load(dir: &Path) -> Result<JobEntry, String> {
        let path = dir.join("job.json");
        let bytes =
            std::fs::read(&path).map_err(|e| format!("reading {}: {e}", path.display()))?;
        let text = std::str::from_utf8(&bytes)
            .map_err(|e| format!("{}: not UTF-8: {e}", path.display()))?;
        let j = Json::parse(text).map_err(|e| format!("{}: {e}", path.display()))?;
        let obj = j
            .as_obj()
            .ok_or_else(|| format!("{}: not a JSON object", path.display()))?;
        const KNOWN: [&str; 7] =
            ["deadline_ms", "error", "id", "pid", "restarts", "state", "version"];
        for key in obj.keys() {
            if !KNOWN.contains(&key.as_str()) {
                return Err(format!(
                    "{}: job manifest field {key:?} is not part of version \
                     {JOB_MANIFEST_VERSION} (version skew? refusing to run a job this \
                     daemon only half-understands)",
                    path.display()
                ));
            }
        }
        let version = j
            .get("version")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("{}: missing version", path.display()))?
            as u64;
        if version != JOB_MANIFEST_VERSION {
            return Err(format!(
                "{}: job manifest version {version} but this daemon speaks version \
                 {JOB_MANIFEST_VERSION}",
                path.display()
            ));
        }
        let id = j
            .get("id")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("{}: missing id", path.display()))?
            .to_string();
        let dir_name = dir.file_name().map(|n| n.to_string_lossy().to_string());
        if dir_name.as_deref() != Some(id.as_str()) {
            return Err(format!(
                "{}: manifest names job {id:?} but lives in {dir_name:?}",
                path.display()
            ));
        }
        let state = JobState::parse(
            j.get("state")
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("{}: missing state", path.display()))?,
        )
        .map_err(|e| format!("{}: {e}", path.display()))?;
        let deadline_ms = match j.get("deadline_ms") {
            None | Some(Json::Null) => None,
            Some(Json::Str(s)) => Some(
                s.parse::<u64>()
                    .map_err(|e| format!("{}: deadline_ms: {e}", path.display()))?,
            ),
            Some(Json::Num(n)) => Some(*n as u64),
            Some(_) => return Err(format!("{}: deadline_ms must be a number", path.display())),
        };
        let spec = JobSpec::load(&dir.join("job-spec.json"))?;
        Ok(JobEntry {
            id,
            dir: dir.to_path_buf(),
            spec,
            state,
            deadline_ms,
            error: j.get("error").and_then(|v| v.as_str()).map(str::to_string),
            restarts: j.get("restarts").and_then(|v| v.as_usize()).unwrap_or(0),
            pid: j.get("pid").and_then(|v| v.as_usize()).map(|p| p as u32),
            cancel_requested: false,
        })
    }

    /// The snapshot object `status`/`list`/`watch` replies carry.
    fn snapshot_json(&self) -> Json {
        let mut pairs = vec![
            ("cells", json::num(self.cells() as f64)),
            ("cmd", json::s(&self.spec.cmd)),
            ("job", json::s(&self.id)),
            ("restarts", json::num(self.restarts as f64)),
            ("state", json::s(self.state.as_str())),
        ];
        if let Some(e) = &self.error {
            pairs.push(("error", json::s(e)));
        }
        json::obj(pairs)
    }
}

/// Shared daemon state behind the connection threads' mutex.
struct Daemon {
    cfg: ServiceConfig,
    jobs: Vec<JobEntry>,
    /// Set by a shutdown request: stop claiming, finish the running job,
    /// exit. Queued jobs stay durably queued for the next daemon.
    draining: bool,
}

impl Daemon {
    fn find(&self, id: &str) -> Option<usize> {
        self.jobs.iter().position(|e| e.id == id)
    }

    fn active_count(&self) -> usize {
        self.jobs.iter().filter(|e| !e.state.is_terminal()).count()
    }
}

/// Scan and strictly validate every job under a service dir (daemon
/// startup, and the manifest-skew refusal test). Job numbers must be
/// contiguous from 1 — job N is lease batch N−1, so a gap would silently
/// shift every later job's lease identity. Returns the number of jobs.
pub fn validate_service_dir(service_dir: &Path) -> Result<usize, String> {
    Ok(scan_jobs(service_dir)?.len())
}

fn scan_jobs(service_dir: &Path) -> Result<Vec<JobEntry>, String> {
    let jobs_dir = service_dir.join(JOBS_DIR);
    if !jobs_dir.is_dir() {
        return Ok(Vec::new());
    }
    let mut names: Vec<String> = Vec::new();
    for entry in std::fs::read_dir(&jobs_dir)
        .map_err(|e| format!("reading {}: {e}", jobs_dir.display()))?
    {
        let name = entry
            .map_err(|e| format!("reading {}: {e}", jobs_dir.display()))?
            .file_name()
            .to_string_lossy()
            .to_string();
        if name.starts_with("job-") {
            names.push(name);
        }
    }
    names.sort();
    for (i, name) in names.iter().enumerate() {
        let expect = job_id(i);
        if *name != expect {
            return Err(format!(
                "{}: expected job dir {expect:?} at position {i} but found {name:?} — job \
                 numbers map to lease batches and must be contiguous from 1",
                jobs_dir.display()
            ));
        }
    }
    names
        .iter()
        .map(|name| JobEntry::load(&jobs_dir.join(name)))
        .collect()
}

/// `job-NNNNNN` for lease-board batch index `idx`.
fn job_id(idx: usize) -> String {
    format!("job-{:06}", idx + 1)
}

/// Run the daemon until a shutdown request (or a fatal service-dir
/// error). Blocks; the address is advertised in `<service-dir>/endpoint`.
pub fn serve(cfg: &ServiceConfig) -> Result<(), String> {
    std::fs::create_dir_all(cfg.service_dir.join(JOBS_DIR))
        .map_err(|e| format!("creating {}: {e}", cfg.service_dir.display()))?;
    let transport = LocalFs::new(&cfg.service_dir)?;
    let mut jobs = scan_jobs(&cfg.service_dir)?;
    recover(&transport, &mut jobs, &cfg.program)?;

    let listener = TcpListener::bind(("127.0.0.1", cfg.port))
        .map_err(|e| format!("binding 127.0.0.1:{}: {e}", cfg.port))?;
    let addr = listener
        .local_addr()
        .map_err(|e| format!("reading bound address: {e}"))?;
    publish_endpoint(&cfg.service_dir, &addr.to_string())?;
    eprintln!(
        "serve: listening on {addr} ({} job(s) recovered, queue capacity {})",
        jobs.len(),
        cfg.queue_capacity
    );

    let daemon = Arc::new(Mutex::new(Daemon { cfg: cfg.clone(), jobs, draining: false }));
    let stop = Arc::new(AtomicBool::new(false));
    {
        let daemon = Arc::clone(&daemon);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || accept_loop(listener, daemon, stop));
    }
    let result = schedule_loop(cfg, &transport, &daemon, &stop);
    // Nudge the accept loop off its blocking accept so it observes `stop`.
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(addr);
    result
}

/// Daemon-restart recovery: any job the dead daemon left `running` gets
/// its orphan child put down (at most one exists — jobs run one at a
/// time), its stale lease attempt expired (the re-dispatch audit marker),
/// and its state reverted to `queued` — unless its run dir already
/// carries the `complete` marker, in which case the work finished and
/// only the bookkeeping was lost.
fn recover(
    transport: &dyn RunDirTransport,
    jobs: &mut [JobEntry],
    program: &Path,
) -> Result<(), String> {
    let board = read_lease_board(transport, jobs.len())?;
    for (idx, entry) in jobs.iter_mut().enumerate() {
        if entry.state != JobState::Running {
            continue;
        }
        if let Some(pid) = entry.pid.take() {
            kill_orphan(pid, program, &entry.spec_path());
        }
        if entry.run_dir().join("complete").exists() {
            entry.state = JobState::Done;
            eprintln!("serve: recovered {} as done (complete marker present)", entry.id);
        } else {
            let state = &board[idx];
            if state.attempts > 0 && !state.done && !state.latest_expired {
                expire_lease(transport, idx, state.attempts - 1)?;
            }
            entry.state = JobState::Queued;
            eprintln!("serve: re-queued {} (daemon died mid-job; child will --resume)", entry.id);
        }
        entry.save_manifest()?;
    }
    Ok(())
}

/// Put down a child orphaned by a SIGKILLed daemon, but only after
/// proving `pid` still runs *our* job (its `/proc` cmdline names this
/// job's spec file) — a recycled pid must never be shot.
fn kill_orphan(pid: u32, program: &Path, spec_path: &Path) {
    let cmdline = match std::fs::read(format!("/proc/{pid}/cmdline")) {
        Ok(bytes) => bytes,
        Err(_) => return, // no such process: nothing to do
    };
    let args: Vec<String> = cmdline
        .split(|b| *b == 0)
        .map(|a| String::from_utf8_lossy(a).to_string())
        .collect();
    let ours = args.iter().any(|a| a == &spec_path.display().to_string())
        && args
            .first()
            .is_some_and(|a| a.contains(&program.file_name().unwrap_or_default().to_string_lossy().to_string()));
    if !ours {
        return;
    }
    eprintln!("serve: stopping orphaned job child pid {pid}");
    let _ = Command::new("kill").args(["-9", &pid.to_string()]).status();
    for _ in 0..100 {
        if !Path::new(&format!("/proc/{pid}")).exists() {
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// One running job's supervision state (scheduler-local).
struct RunningJob {
    idx: usize,
    child: Child,
    lease: Lease,
    started: Instant,
}

/// The sequential scheduler: claim the lowest queued job through the
/// lease board, supervise its child (crash-restart budget, deadline,
/// cancel), heartbeat its lease, publish `done`. One job at a time —
/// concurrency inside a job belongs to its own worker pool, and
/// sequential execution keeps per-job determinism trivially intact.
fn schedule_loop(
    cfg: &ServiceConfig,
    transport: &dyn RunDirTransport,
    daemon: &Arc<Mutex<Daemon>>,
    stop: &Arc<AtomicBool>,
) -> Result<(), String> {
    let mut current: Option<RunningJob> = None;
    loop {
        std::thread::sleep(Duration::from_millis(cfg.poll_ms.max(1)));
        if let Some(run) = current.as_mut() {
            let (cancel, deadline_ms) = {
                let d = daemon.lock().unwrap();
                (d.jobs[run.idx].cancel_requested, d.jobs[run.idx].deadline_ms)
            };
            let deadline_hit = deadline_ms
                .is_some_and(|d| run.started.elapsed() >= Duration::from_millis(d));
            if cancel || deadline_hit {
                let _ = run.child.kill();
                let _ = run.child.wait();
                let mut d = daemon.lock().unwrap();
                let entry = &mut d.jobs[run.idx];
                entry.pid = None;
                if cancel {
                    entry.state = JobState::Cancelled;
                } else {
                    entry.state = JobState::Failed;
                    entry.error =
                        Some(format!("deadline of {}ms exceeded", deadline_ms.unwrap_or(0)));
                }
                entry.save_manifest()?;
                // Audit marker: the attempt ended without `done`.
                expire_lease(transport, run.idx, run.lease.attempt)?;
                current = None;
                continue;
            }
            match run.child.try_wait() {
                Ok(Some(status)) if status.success() => {
                    run.lease.done = true;
                    transport.publish(&run.lease.rel(), &run.lease.to_bytes())?;
                    let mut d = daemon.lock().unwrap();
                    let entry = &mut d.jobs[run.idx];
                    entry.pid = None;
                    entry.state = JobState::Done;
                    entry.save_manifest()?;
                    eprintln!("serve: {} done ({} cell(s))", entry.id, entry.cells());
                    current = None;
                }
                Ok(Some(status)) => {
                    let mut d = daemon.lock().unwrap();
                    let entry = &mut d.jobs[run.idx];
                    if entry.restarts < cfg.max_restarts {
                        entry.restarts += 1;
                        entry.save_manifest()?;
                        eprintln!(
                            "serve: {} child exited with {status}; restart {}/{} (--resume)",
                            entry.id, entry.restarts, cfg.max_restarts
                        );
                        let child = spawn_job_child(cfg, entry)?;
                        entry.pid = Some(child.id());
                        entry.save_manifest()?;
                        run.child = child;
                    } else {
                        entry.pid = None;
                        entry.state = JobState::Failed;
                        entry.error = Some(format!(
                            "child exited with {status} after {} restart(s)",
                            entry.restarts
                        ));
                        entry.save_manifest()?;
                        eprintln!("serve: {} failed: {}", entry.id, status);
                        expire_lease(transport, run.idx, run.lease.attempt)?;
                        current = None;
                    }
                }
                Ok(None) => {
                    let progress = daemon.lock().unwrap().jobs[run.idx].progress();
                    if progress != run.lease.progress {
                        run.lease.progress = progress;
                        transport.publish(&run.lease.rel(), &run.lease.to_bytes())?;
                    }
                }
                Err(e) => return Err(format!("waiting on job child: {e}")),
            }
            continue;
        }
        // Idle: claim the next queued job (unless draining).
        let (total, queued, draining) = {
            let d = daemon.lock().unwrap();
            let queued: Vec<usize> = d
                .jobs
                .iter()
                .enumerate()
                .filter(|(_, e)| e.state == JobState::Queued && !e.cancel_requested)
                .map(|(i, _)| i)
                .collect();
            (d.jobs.len(), queued, d.draining)
        };
        if draining {
            return Ok(());
        }
        if queued.is_empty() {
            continue;
        }
        let board = read_lease_board(transport, total)?;
        let claimable: Vec<_> = queued
            .iter()
            .map(|i| board[*i].clone())
            .filter(|s| s.claimable())
            .collect();
        let Some(lease) = claim_next_batch(transport, &claimable, SCHEDULER_ID)? else {
            continue;
        };
        let idx = lease.batch;
        let mut d = daemon.lock().unwrap();
        let entry = &mut d.jobs[idx];
        match spawn_job_child(cfg, entry) {
            Ok(child) => {
                entry.pid = Some(child.id());
                entry.state = JobState::Running;
                entry.save_manifest()?;
                eprintln!("serve: {} running ({})", entry.id, entry.spec.cmd);
                current =
                    Some(RunningJob { idx, child, lease, started: Instant::now() });
            }
            Err(e) => {
                entry.state = JobState::Failed;
                entry.error = Some(e);
                entry.save_manifest()?;
                expire_lease(transport, idx, lease.attempt)?;
            }
        }
    }
}

/// Spawn one job's child: `<program> <cmd> --job-spec … --run-dir …
/// --resume [--memory-dir <overlay>]`, stdout/stderr appended to the
/// job's log. The identity travels *only* through the spec file — the
/// same entry point a human invocation takes — so the service path
/// cannot drift from the direct path.
fn spawn_job_child(cfg: &ServiceConfig, entry: &JobEntry) -> Result<Child, String> {
    let log = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(entry.dir.join("job.log"))
        .map_err(|e| format!("opening job log: {e}"))?;
    let log_err = log.try_clone().map_err(|e| format!("cloning job log: {e}"))?;
    let mut cmd = Command::new(&cfg.program);
    cmd.arg(&entry.spec.cmd)
        .arg("--job-spec")
        .arg(entry.spec_path())
        .arg("--run-dir")
        .arg(entry.run_dir())
        .arg("--resume")
        .stdin(Stdio::null())
        .stdout(log)
        .stderr(log_err);
    if let Some(base) = &cfg.base_memory {
        let overlay = entry.overlay_dir();
        crate::memory::long_term::create_overlay(base, &overlay)?;
        cmd.arg("--memory-dir").arg(&overlay);
    }
    cmd.spawn()
        .map_err(|e| format!("spawning {} for {}: {e}", cfg.program.display(), entry.id))
}

/// Atomically publish the endpoint file.
fn publish_endpoint(service_dir: &Path, addr: &str) -> Result<(), String> {
    let path = service_dir.join(ENDPOINT_FILE);
    let tmp = service_dir.join("endpoint.tmp");
    std::fs::write(&tmp, format!("{addr}\n")).map_err(|e| format!("writing {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, &path).map_err(|e| format!("publishing {}: {e}", path.display()))
}

// ------------------------------------------------------------------------
// Connection handling
// ------------------------------------------------------------------------

fn accept_loop(listener: TcpListener, daemon: Arc<Mutex<Daemon>>, stop: Arc<AtomicBool>) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let daemon = Arc::clone(&daemon);
        std::thread::spawn(move || {
            let _ = handle_conn(stream, &daemon);
        });
    }
}

fn handle_conn(stream: TcpStream, daemon: &Arc<Mutex<Daemon>>) -> Result<(), String> {
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .map_err(|e| e.to_string())?;
    let mut reader = BufReader::new(stream.try_clone().map_err(|e| e.to_string())?);
    let mut writer = stream;
    let mut line = String::new();
    if reader.read_line(&mut line).map_err(|e| e.to_string())? == 0 {
        return Ok(()); // client connected and left (the shutdown nudge)
    }
    let req = match Request::parse(line.trim()) {
        Ok(r) => r,
        Err(e) => return send(&mut writer, &response_err(&e, false)),
    };
    match req {
        Request::Ping => send(&mut writer, &response_ok(vec![("service", json::s("kernelskill-serve"))])),
        Request::Submit { spec, deadline_ms } => {
            let resp = submit(daemon, spec, deadline_ms);
            send(&mut writer, &resp)
        }
        Request::Status { job } => {
            let d = daemon.lock().unwrap();
            let resp = match d.find(&job) {
                Some(i) => response_ok(vec![("status", d.jobs[i].snapshot_json())]),
                None => response_err(&format!("no such job {job:?}"), false),
            };
            drop(d);
            send(&mut writer, &resp)
        }
        Request::List => {
            let d = daemon.lock().unwrap();
            let snaps: Vec<Json> = d.jobs.iter().map(|e| e.snapshot_json()).collect();
            drop(d);
            send(&mut writer, &response_ok(vec![("jobs", Json::Arr(snaps))]))
        }
        Request::Cancel { job } => {
            let resp = cancel(daemon, &job);
            send(&mut writer, &resp)
        }
        Request::Watch { job } => watch(&mut writer, daemon, &job),
        Request::Shutdown => {
            daemon.lock().unwrap().draining = true;
            send(&mut writer, &response_ok(vec![("draining", Json::Bool(true))]))
        }
    }
}

fn send(writer: &mut TcpStream, j: &Json) -> Result<(), String> {
    writeln!(writer, "{j}").map_err(|e| format!("writing response: {e}"))?;
    writer.flush().map_err(|e| format!("flushing response: {e}"))
}

fn submit(daemon: &Arc<Mutex<Daemon>>, spec: JobSpec, deadline_ms: Option<u64>) -> Json {
    let mut d = daemon.lock().unwrap();
    if d.draining {
        return response_err("daemon is draining (shutdown requested)", false);
    }
    if d.active_count() >= d.cfg.queue_capacity {
        return response_err(
            &format!(
                "queue full ({} active job(s), capacity {}): backpressure — retry after a \
                 job finishes",
                d.active_count(),
                d.cfg.queue_capacity
            ),
            true,
        );
    }
    let idx = d.jobs.len();
    let id = job_id(idx);
    let dir = d.cfg.service_dir.join(JOBS_DIR).join(&id);
    let entry = JobEntry {
        id: id.clone(),
        dir: dir.clone(),
        spec,
        state: JobState::Queued,
        deadline_ms,
        error: None,
        restarts: 0,
        pid: None,
        cancel_requested: false,
    };
    let published = std::fs::create_dir_all(&dir)
        .map_err(|e| format!("creating {}: {e}", dir.display()))
        .and_then(|()| entry.spec.save(&entry.spec_path()))
        .and_then(|()| entry.save_manifest());
    match published {
        Ok(()) => {
            d.jobs.push(entry);
            response_ok(vec![("job", json::s(&id)), ("state", json::s("queued"))])
        }
        Err(e) => response_err(&e, false),
    }
}

fn cancel(daemon: &Arc<Mutex<Daemon>>, job: &str) -> Json {
    let mut d = daemon.lock().unwrap();
    let Some(i) = d.find(job) else {
        return response_err(&format!("no such job {job:?}"), false);
    };
    let entry = &mut d.jobs[i];
    match entry.state {
        JobState::Queued => {
            entry.state = JobState::Cancelled;
            match entry.save_manifest() {
                Ok(()) => response_ok(vec![
                    ("job", json::s(job)),
                    ("state", json::s(entry.state.as_str())),
                ]),
                Err(e) => response_err(&e, false),
            }
        }
        JobState::Running => {
            entry.cancel_requested = true;
            response_ok(vec![
                ("cancelling", Json::Bool(true)),
                ("job", json::s(job)),
                ("state", json::s("running")),
            ])
        }
        state => response_ok(vec![
            ("job", json::s(job)),
            ("note", json::s("already terminal")),
            ("state", json::s(state.as_str())),
        ]),
    }
}

/// Stream snapshots to the watcher whenever (state, cells) changes, then
/// a final `{"event":"end",…}` line once the job is terminal.
fn watch(writer: &mut TcpStream, daemon: &Arc<Mutex<Daemon>>, job: &str) -> Result<(), String> {
    let (found, poll_ms) = {
        let d = daemon.lock().unwrap();
        (d.find(job).is_some(), d.cfg.poll_ms)
    };
    if !found {
        return send(writer, &response_err(&format!("no such job {job:?}"), false));
    }
    let mut last: Option<(JobState, u64)> = None;
    loop {
        let (snapshot, state) = {
            let d = daemon.lock().unwrap();
            let i = d.find(job).expect("jobs are never removed");
            (d.jobs[i].snapshot_json(), d.jobs[i].state)
        };
        let cells = snapshot.get("cells").and_then(|c| c.as_f64()).unwrap_or(0.0) as u64;
        if last != Some((state, cells)) {
            last = Some((state, cells));
            let mut event = vec![("event", json::s("state"))];
            if let Json::Obj(map) = &snapshot {
                for (k, v) in map {
                    event.push((k.as_str(), v.clone()));
                }
            }
            send(writer, &json::obj(event))?;
        }
        if state.is_terminal() {
            let mut end = vec![("event", json::s("end")), ("job", json::s(job)),
                ("state", json::s(state.as_str()))];
            if let Some(e) = snapshot.get("error").and_then(|e| e.as_str()) {
                end.push(("error", json::s(e)));
            }
            return send(writer, &json::obj(end));
        }
        std::thread::sleep(Duration::from_millis(poll_ms.max(1)));
    }
}

// ------------------------------------------------------------------------
// Client (the `jobs` CLI and tests)
// ------------------------------------------------------------------------

/// A client handle on one daemon, resolved through its service dir's
/// endpoint file.
#[derive(Debug, Clone)]
pub struct Client {
    addr: String,
}

impl Client {
    /// Resolve and probe the daemon behind `service_dir`, retrying for a
    /// few seconds while it comes up (endpoint file missing or connection
    /// refused — e.g. right after `serve` was launched).
    pub fn connect(service_dir: &Path) -> Result<Client, String> {
        let endpoint = service_dir.join(ENDPOINT_FILE);
        let mut last = String::new();
        for _ in 0..CONNECT_ATTEMPTS {
            match std::fs::read_to_string(&endpoint) {
                Ok(text) => {
                    let addr = text.trim().to_string();
                    match TcpStream::connect(&addr) {
                        Ok(_) => return Ok(Client { addr }),
                        Err(e) => last = format!("connecting {addr}: {e}"),
                    }
                }
                Err(e) => last = format!("reading {}: {e}", endpoint.display()),
            }
            std::thread::sleep(Duration::from_millis(CONNECT_RETRY_MS));
        }
        Err(format!(
            "no daemon reachable via {} after {:.1}s ({last}) — is `serve` running?",
            endpoint.display(),
            (CONNECT_ATTEMPTS as u64 * CONNECT_RETRY_MS) as f64 / 1000.0
        ))
    }

    /// One-shot request: send a line, read the single response line. An
    /// `ok:false` reply becomes an `Err` (with a `[backpressure]` prefix
    /// when the daemon flagged it).
    pub fn request(&self, req: &Request) -> Result<Json, String> {
        let mut lines = self.open(req)?;
        let line = lines
            .pop_front()
            .ok_or("daemon closed the connection without replying")?;
        parse_reply(&line)
    }

    /// Watch a job to its terminal state, invoking `on_event` per
    /// streamed event line. Returns the final (`event:"end"`) object.
    pub fn watch(
        &self,
        job: &str,
        mut on_event: impl FnMut(&Json),
    ) -> Result<Json, String> {
        let stream = TcpStream::connect(&self.addr).map_err(|e| format!("connecting {}: {e}", self.addr))?;
        let mut writer = stream.try_clone().map_err(|e| e.to_string())?;
        writeln!(writer, "{}", Request::Watch { job: job.to_string() }.to_json())
            .map_err(|e| format!("sending watch: {e}"))?;
        let reader = BufReader::new(stream);
        let mut last = None;
        for line in reader.lines() {
            let line = line.map_err(|e| format!("reading watch stream: {e}"))?;
            let j = parse_reply(&line)?;
            on_event(&j);
            let is_end = j.get("event").and_then(|e| e.as_str()) == Some("end");
            last = Some(j);
            if is_end {
                break;
            }
        }
        last.ok_or_else(|| "watch stream ended without events".to_string())
    }

    fn open(&self, req: &Request) -> Result<VecDeque<String>, String> {
        let stream = TcpStream::connect(&self.addr)
            .map_err(|e| format!("connecting {}: {e}", self.addr))?;
        let mut writer = stream.try_clone().map_err(|e| e.to_string())?;
        writeln!(writer, "{}", req.to_json()).map_err(|e| format!("sending request: {e}"))?;
        let reader = BufReader::new(stream);
        let mut lines = VecDeque::new();
        for line in reader.lines() {
            lines.push_back(line.map_err(|e| format!("reading response: {e}"))?);
            break; // unary ops: one line
        }
        Ok(lines)
    }
}

/// Parse one response line; `ok:false` replies become errors.
fn parse_reply(line: &str) -> Result<Json, String> {
    let j = Json::parse(line).map_err(|e| format!("daemon reply does not parse: {e}"))?;
    if matches!(j.get("ok"), Some(Json::Bool(false))) {
        let msg = j.get("error").and_then(|e| e.as_str()).unwrap_or("unknown error");
        let bp = matches!(j.get("backpressure"), Some(Json::Bool(true)));
        return Err(if bp { format!("[backpressure] {msg}") } else { msg.to_string() });
    }
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ks-service-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn job_manifest_roundtrips_and_refuses_skew() {
        let dir = tmp_dir("manifest");
        let job_dir = dir.join(JOBS_DIR).join("job-000001");
        std::fs::create_dir_all(&job_dir).unwrap();
        let entry = JobEntry {
            id: "job-000001".to_string(),
            dir: job_dir.clone(),
            spec: JobSpec::default(),
            state: JobState::Queued,
            deadline_ms: Some(30_000),
            error: None,
            restarts: 1,
            pid: Some(4242),
            cancel_requested: false,
        };
        entry.spec.save(&entry.spec_path()).unwrap();
        entry.save_manifest().unwrap();
        let back = JobEntry::load(&job_dir).unwrap();
        assert_eq!(back.state, JobState::Queued);
        assert_eq!(back.deadline_ms, Some(30_000));
        assert_eq!(back.restarts, 1);
        assert_eq!(back.pid, Some(4242));
        assert_eq!(back.spec, entry.spec);
        assert_eq!(validate_service_dir(&dir).unwrap(), 1);

        // Version skew and unknown fields are refused loudly.
        let manifest = job_dir.join("job.json");
        let text = std::fs::read_to_string(&manifest).unwrap();
        std::fs::write(&manifest, text.replace("\"version\":1", "\"version\":9")).unwrap();
        let err = validate_service_dir(&dir).unwrap_err();
        assert!(err.contains("version 9"), "{err}");
        std::fs::write(&manifest, text.replace("\"restarts\"", "\"restartz\"")).unwrap();
        let err = validate_service_dir(&dir).unwrap_err();
        assert!(err.contains("restartz"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn job_dirs_must_be_contiguous() {
        let dir = tmp_dir("gap");
        let job_dir = dir.join(JOBS_DIR).join("job-000002");
        std::fs::create_dir_all(&job_dir).unwrap();
        let entry = JobEntry {
            id: "job-000002".to_string(),
            dir: job_dir.clone(),
            spec: JobSpec::default(),
            state: JobState::Queued,
            deadline_ms: None,
            error: None,
            restarts: 0,
            pid: None,
            cancel_requested: false,
        };
        entry.spec.save(&entry.spec_path()).unwrap();
        entry.save_manifest().unwrap();
        let err = validate_service_dir(&dir).unwrap_err();
        assert!(err.contains("contiguous"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn endpoint_file_roundtrips() {
        let dir = tmp_dir("endpoint");
        publish_endpoint(&dir, "127.0.0.1:45678").unwrap();
        let text = std::fs::read_to_string(dir.join(ENDPOINT_FILE)).unwrap();
        assert_eq!(text, "127.0.0.1:45678\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
