//! Shard launchers: one command that runs a whole distributed suite —
//! on one machine (`launch`) or across many (`launch --manifest` +
//! `worker`).
//!
//! **Single-machine `launch`** replaces the hand-run N-process + `merge`
//! dance: it spawns `--shards N` child processes of this very binary
//! (std::process only — nothing to install), one per shard of the cell
//! matrix, each streaming to `<run-dir>/shard-<i>`; monitors them;
//! restarts a crashed child with `--resume` (children are always spawned
//! resumable, so a restart picks up exactly at the checkpointed cells);
//! follows the shard checkpoints live through [`MergeWatcher`]; and
//! finalizes the streaming merge into `<run-dir>` itself once every child
//! has exited cleanly. The merged output is byte-identical to a
//! single-process run of the same matrix — the `tests/launcher.rs` battery
//! and the CI `launch-smoke` job (which force-kills a child mid-run) pin
//! that down.
//!
//! **Cross-machine launch** splits the same dance over run-dir transports
//! (`coordinator::transport`): each machine runs the [`run_worker`] loop —
//! spawn and supervise its manifest-assigned slice of the global shards,
//! publish their run dirs through its transport, pull the fleet's exchange
//! deltas back down — while one machine runs the [`launch_workers`]
//! pull-based supervisor: tail-sync every worker's checkpoints into local
//! mirrors, feed them to the *same* [`MergeWatcher`], relay exchange
//! deltas between workers, and finalize. Because every byte still flows
//! through the ordinary merge path, the final output is byte-identical to
//! a single-process run — independent of worker placement, sync timing,
//! worker kills, and interrupted transfers (`tests/distributed.rs`, CI
//! `multi-node-smoke`).
//!
//! With [`LaunchConfig::exchange_epoch`] set, children run with epoch-based
//! live memory exchange (see `coordinator::scheduler` and
//! `docs/memory-formats.md`): late shards retrieve against skills learned
//! anywhere in the fleet, and the result is still a pure function of
//! (matrix, base memory, epoch length) — byte-identical to a `--shards 1`
//! launch with the same epoch length.
//!
//! **Elastic fleets** (a manifest with `total_batches` + a shared `lease`
//! transport instead of shard ranges) replace static placement with lease
//! claiming: the matrix is cut into contiguous cell batches, each worker's
//! [`run_worker`] loop claims the next unleased batch by atomically
//! publishing a lease file (first publish wins), runs it as a child with
//! `--batch-index`, and heartbeats the lease with its *progress counter*
//! (published checkpoint bytes — deliberately not a wall-clock mtime,
//! which a slow filesystem or a paused straggler defeats). The
//! [`launch_workers`] coordinator watches the lease board and re-dispatches
//! any batch whose counter stops advancing by publishing an `.expired`
//! marker; a re-claimed batch recomputes the same deterministic bytes, so
//! duplicated attempts collapse in the bit-identical merge path and the
//! final output stays byte-identical to a single-process run regardless of
//! placement, kills, and re-dispatch (`tests/distributed.rs`, CI
//! `elastic-smoke`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use super::checkpoint::RunDir;
use super::merge::{MergeReport, MergeWatcher};
use super::scheduler::EXCHANGE_TIMEOUT_EXIT;
use super::transport::{
    claim_next_batch, expire_lease, parse_up_batch_name, read_lease_board, up_shard_rel,
    ExchangeHub, ExchangePull, ExchangePush, RunDirTransport, ShardPull, ShardPush,
    WorkerManifest, WorkerSpec, UP_EXCHANGE,
};

/// What to launch and how to supervise it.
#[derive(Debug, Clone)]
pub struct LaunchConfig {
    /// Binary to spawn — normally `std::env::current_exe()`.
    pub program: PathBuf,
    /// Subcommand the children run (`suite`, `table1`, …); it must accept
    /// `--run-dir/--shards/--shard-index/--resume`.
    pub subcommand: String,
    /// Flags forwarded verbatim to every child (strategy, level, seeds, …).
    pub passthrough: Vec<String>,
    /// Parent directory: shard `i` streams to `<run_dir>/shard-<i>`, child
    /// logs go to `<run_dir>/shard-<i>.log`, and the final merge lands in
    /// `<run_dir>` itself.
    pub run_dir: PathBuf,
    /// Number of shard processes to run (>= 1).
    pub shards: usize,
    /// Crash budget per shard: a child that exits non-zero is relaunched
    /// (with `--resume`) at most this many times before the launch fails.
    pub max_restarts: usize,
    /// Supervision poll interval in milliseconds.
    pub poll_ms: u64,
    /// Enable live memory exchange with this epoch length (cells); the
    /// exchange dir is `<run_dir>/exchange`.
    pub exchange_epoch: Option<usize>,
    /// Extra environment variables for the children (used by the crash-test
    /// hook in CI and tests).
    pub child_env: Vec<(String, String)>,
}

impl LaunchConfig {
    /// A launch of `shards` children of `program` running `subcommand`
    /// under `run_dir`, with default supervision settings.
    pub fn new<P: Into<PathBuf>, Q: Into<PathBuf>>(
        program: P,
        subcommand: &str,
        run_dir: Q,
        shards: usize,
    ) -> LaunchConfig {
        LaunchConfig {
            program: program.into(),
            subcommand: subcommand.to_string(),
            passthrough: Vec::new(),
            run_dir: run_dir.into(),
            shards,
            max_restarts: 2,
            poll_ms: 50,
            exchange_epoch: None,
            child_env: Vec::new(),
        }
    }
}

/// One shard's supervision outcome.
#[derive(Debug, Clone)]
pub struct ShardOutcome {
    /// Shard index.
    pub index: usize,
    /// The shard's run directory.
    pub dir: PathBuf,
    /// The shard's captured stdout/stderr log.
    pub log: PathBuf,
    /// Times the child was relaunched after a non-zero exit.
    pub restarts: usize,
}

/// Outcome of a successful [`launch`].
#[derive(Debug, Clone)]
pub struct LaunchReport {
    /// Per-shard supervision outcomes.
    pub shards: Vec<ShardOutcome>,
    /// The final streaming-merge report.
    pub merge: MergeReport,
}

impl LaunchReport {
    /// Human-readable multi-line summary (the `launch` CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let restarts: usize = self.shards.iter().map(|s| s.restarts).sum();
        out.push_str(&format!(
            "launched {} shard(s), {} crash-restart(s)\n",
            self.shards.len(),
            restarts
        ));
        for s in &self.shards {
            out.push_str(&format!(
                "  shard {}  {} restart(s)  log {}\n",
                s.index,
                s.restarts,
                s.log.display()
            ));
        }
        out.push_str(&self.merge.render());
        out
    }
}

/// The run directory shard `i` of a launch streams to.
pub fn shard_dir(run_dir: &Path, index: usize) -> PathBuf {
    run_dir.join(format!("shard-{index}"))
}

/// One supervised child.
struct ShardProc {
    index: usize,
    child: Option<Child>,
    restarts: usize,
    /// Restarts after a *restartable* exit ([`EXCHANGE_TIMEOUT_EXIT`] — an
    /// exchange wait that timed out because a peer died and was
    /// re-dispatched). Tracked separately so waiting out a slow fleet does
    /// not burn the crash budget.
    tempfail_restarts: usize,
    done: bool,
}

/// Restartable (`EX_TEMPFAIL`) exits are capped separately from the crash
/// budget — generously, but not unboundedly, so a fleet whose peer truly
/// never comes back still fails loudly instead of spinning forever.
const TEMPFAIL_RESTART_CAP: usize = 50;

/// Kills every still-running child on scope exit, so an error return (or a
/// panic) never leaks orphan shard processes.
struct ReapOnDrop<'a>(&'a mut Vec<ShardProc>);

impl Drop for ReapOnDrop<'_> {
    fn drop(&mut self) {
        reap_all(self.0);
    }
}

fn reap_all(procs: &mut [ShardProc]) {
    for s in procs.iter_mut() {
        if let Some(child) = s.child.as_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// Everything needed to spawn (or respawn) one shard child process.
struct ChildParams {
    program: PathBuf,
    subcommand: String,
    passthrough: Vec<String>,
    /// Run dir the child streams to.
    dir: PathBuf,
    /// Captured stdout/stderr log.
    log_path: PathBuf,
    /// Fleet-wide slice count (shards, or lease batches in batch mode).
    total_shards: usize,
    /// This child's global slice index.
    index: usize,
    /// Spawn with `--batch-index/--batch-count` (elastic lease batch)
    /// instead of `--shards/--shard-index`.
    batch_mode: bool,
    /// Live memory exchange: (shared exchange dir, epoch length).
    exchange: Option<(PathBuf, usize)>,
    env: Vec<(String, String)>,
}

/// The passthrough flags for one worker's shard children: the launch-wide
/// flags plus the worker's manifest `device` preset (forwarded as
/// `--device <name>`), if any — how a heterogeneous fleet pins each
/// machine to its own hardware model. A passthrough that carries
/// `--job-spec` is left alone: the `worker` CLI folds the manifest device
/// into the spec itself before fanning it out, so the children already
/// receive exactly one identity artifact. A manifest device that collides
/// with a launch-wide `--device` flag is refused up front: the two would
/// silently disagree about which one wins.
fn worker_passthrough(base: &[String], spec: &WorkerSpec) -> Result<Vec<String>, String> {
    let mut out = base.to_vec();
    if let Some(device) = &spec.device {
        if base.iter().any(|a| a == "--job-spec") {
            return Ok(out);
        }
        if base.iter().any(|a| a == "--device") {
            return Err(format!(
                "worker {:?}: the manifest assigns device {:?} but the launch \
                 passthrough already carries --device; drop one of them",
                spec.id, device
            ));
        }
        out.push("--device".to_string());
        out.push(device.clone());
    }
    Ok(out)
}

impl ChildParams {
    /// "shard 3" / "batch 3" — for logs and error messages.
    fn label(&self) -> String {
        if self.batch_mode {
            format!("batch {}", self.index)
        } else {
            format!("shard {}", self.index)
        }
    }
}

fn spawn_child(p: &ChildParams, resume_note: bool) -> Result<Child, String> {
    let log = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&p.log_path)
        .map_err(|e| format!("opening {}: {e}", p.log_path.display()))?;
    let log_err = log
        .try_clone()
        .map_err(|e| format!("opening {}: {e}", p.log_path.display()))?;
    let mut cmd = Command::new(&p.program);
    cmd.arg(&p.subcommand).args(&p.passthrough).arg("--run-dir").arg(&p.dir);
    if p.batch_mode {
        cmd.arg("--batch-count")
            .arg(p.total_shards.to_string())
            .arg("--batch-index")
            .arg(p.index.to_string());
    } else {
        cmd.arg("--shards")
            .arg(p.total_shards.to_string())
            .arg("--shard-index")
            .arg(p.index.to_string());
    }
    // Children are always resumable: the first run of a fresh dir is a
    // no-op resume, and a crash-restart picks up at the checkpoint.
    cmd.arg("--resume");
    if let Some((dir, epoch)) = &p.exchange {
        cmd.arg("--exchange-dir")
            .arg(dir)
            .arg("--exchange-epoch")
            .arg(epoch.to_string());
    }
    for (k, v) in &p.env {
        cmd.env(k, v);
    }
    cmd.stdin(Stdio::null()).stdout(log).stderr(log_err);
    let child = cmd
        .spawn()
        .map_err(|e| format!("spawning {} ({}): {e}", p.label(), p.program.display()))?;
    if resume_note {
        crate::log_warn!("{}: relaunched with --resume (pid {})", p.label(), child.id());
    } else {
        crate::log_info!("{}: spawned (pid {})", p.label(), child.id());
    }
    Ok(child)
}

fn shard_params(cfg: &LaunchConfig, index: usize) -> ChildParams {
    ChildParams {
        program: cfg.program.clone(),
        subcommand: cfg.subcommand.clone(),
        passthrough: cfg.passthrough.clone(),
        dir: shard_dir(&cfg.run_dir, index),
        log_path: cfg.run_dir.join(format!("shard-{index}.log")),
        total_shards: cfg.shards,
        index,
        batch_mode: false,
        exchange: cfg
            .exchange_epoch
            .map(|epoch| (cfg.run_dir.join("exchange"), epoch)),
        env: cfg.child_env.clone(),
    }
}

/// One supervision pass over the children: reap clean exits, restart
/// crashes with `--resume` (bounded by `max_restarts`), and report whether
/// every child is done. A shard that exhausts its crash budget is a fatal
/// error naming its log.
fn poll_procs(
    procs: &mut [ShardProc],
    max_restarts: usize,
    log_dir: &Path,
    respawn: &mut dyn FnMut(usize) -> Result<Child, String>,
) -> Result<bool, String> {
    let mut all_done = true;
    for s in procs.iter_mut() {
        if s.done {
            continue;
        }
        all_done = false;
        let Some(child) = s.child.as_mut() else {
            continue;
        };
        match child.try_wait() {
            Ok(None) => {}
            Ok(Some(status)) if status.success() => {
                s.child = None;
                s.done = true;
            }
            Ok(Some(status)) if status.code() == Some(EXCHANGE_TIMEOUT_EXIT) => {
                // Restartable: the child gave up waiting for a peer's
                // exchange delta (the peer died, or stalled and was
                // re-dispatched). Not the child's fault — relaunch with
                // `--resume` without burning its crash budget, under a
                // separate generous cap.
                s.child = None;
                if s.tempfail_restarts >= TEMPFAIL_RESTART_CAP {
                    return Err(format!(
                        "shard {} is starved of exchange deltas: {} restartable \
                         timeout exit(s) without the peer delta appearing; see {}",
                        s.index,
                        s.tempfail_restarts,
                        log_dir.join(format!("shard-{}.log", s.index)).display()
                    ));
                }
                s.tempfail_restarts += 1;
                crate::log_warn!(
                    "shard {} hit a restartable exchange-wait timeout; relaunching \
                     ({}/{} restartable exits)",
                    s.index,
                    s.tempfail_restarts,
                    TEMPFAIL_RESTART_CAP
                );
                s.child = Some(respawn(s.index)?);
            }
            Ok(Some(status)) => {
                s.child = None;
                if s.restarts >= max_restarts {
                    return Err(format!(
                        "shard {} failed with {status} after {} restart(s); see {}",
                        s.index,
                        s.restarts,
                        log_dir.join(format!("shard-{}.log", s.index)).display()
                    ));
                }
                s.restarts += 1;
                crate::log_warn!(
                    "shard {} exited with {status}; restarting ({}/{})",
                    s.index,
                    s.restarts,
                    max_restarts
                );
                s.child = Some(respawn(s.index)?);
            }
            Err(e) => return Err(format!("waiting on shard {}: {e}", s.index)),
        }
    }
    Ok(all_done)
}

/// Spawn, supervise, crash-restart, and merge a sharded run. See the module
/// docs; returns once the merged output in `cfg.run_dir` is complete.
pub fn launch(cfg: &LaunchConfig) -> Result<LaunchReport, String> {
    if cfg.shards == 0 {
        return Err("launch needs --shards >= 1".to_string());
    }
    if let Some(0) = cfg.exchange_epoch {
        return Err("--exchange-epoch must be >= 1".to_string());
    }
    std::fs::create_dir_all(&cfg.run_dir)
        .map_err(|e| format!("creating {}: {e}", cfg.run_dir.display()))?;
    let out_rd = RunDir::open(&cfg.run_dir)
        .map_err(|e| format!("opening {}: {e}", cfg.run_dir.display()))?;
    if out_rd.has_results() {
        return Err(format!(
            "{} already holds merged results; pick a fresh --run-dir",
            cfg.run_dir.display()
        ));
    }

    // Create the shard dirs up front so the streaming merge can safely
    // canonicalize them before the children get going.
    let shard_dirs: Vec<PathBuf> = (0..cfg.shards)
        .map(|i| {
            let d = shard_dir(&cfg.run_dir, i);
            std::fs::create_dir_all(&d).map(|_| d)
        })
        .collect::<Result<_, _>>()
        .map_err(|e| format!("creating shard dirs: {e}"))?;
    let mut watcher = MergeWatcher::new(&cfg.run_dir, &shard_dirs)?;

    let mut procs: Vec<ShardProc> = Vec::new();
    for index in 0..cfg.shards {
        procs.push(ShardProc {
            index,
            child: Some(spawn_child(&shard_params(cfg, index), false)?),
            restarts: 0,
            tempfail_restarts: 0,
            done: false,
        });
    }

    let mut last_cells = usize::MAX;
    {
        let guard = ReapOnDrop(&mut procs);
        loop {
            // Any error below returns out of `launch`; the guard kills the
            // surviving children on its way out.
            let all_done = poll_procs(&mut *guard.0, cfg.max_restarts, &cfg.run_dir, &mut |i| {
                spawn_child(&shard_params(cfg, i), true)
            })?;
            // Live streaming merge: fold whatever the shards appended since
            // the last cycle and narrate progress on change.
            let status = watcher.poll()?;
            if status.cells != last_cells {
                last_cells = status.cells;
                crate::log_info!("launch: {}", status.render());
            }
            if all_done {
                break;
            }
            std::thread::sleep(Duration::from_millis(cfg.poll_ms.max(1)));
        }
        // All children exited cleanly; nothing left for the guard to reap.
    }

    let merge = watcher.finalize()?;
    out_rd
        .mark_complete()
        .map_err(|e| format!("writing completion marker: {e}"))?;
    Ok(LaunchReport {
        shards: procs
            .iter()
            .map(|s| ShardOutcome {
                index: s.index,
                dir: shard_dir(&cfg.run_dir, s.index),
                log: cfg.run_dir.join(format!("shard-{}.log", s.index)),
                restarts: s.restarts,
            })
            .collect(),
        merge,
    })
}

// ------------------------------------------------------------------------
// Cross-machine: the worker side
// ------------------------------------------------------------------------

/// What one worker machine runs: its manifest row's shard range, published
/// through its manifest row's transport.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Binary to spawn — normally `std::env::current_exe()`.
    pub program: PathBuf,
    /// Subcommand the shard children run (`suite`, `table1`, …). Every
    /// worker of a fleet must use the same subcommand and passthrough
    /// flags; a mismatch is caught by the coordinator's manifest
    /// compatibility check at merge time.
    pub subcommand: String,
    /// Flags forwarded verbatim to every shard child.
    pub passthrough: Vec<String>,
    /// The validated fleet manifest.
    pub manifest: WorkerManifest,
    /// Which manifest row this machine is.
    pub worker_id: String,
    /// Local scratch directory: shard run dirs (unless the transport is
    /// zero-copy), child logs, and the local exchange mirror live here.
    pub run_dir: PathBuf,
    /// Crash budget per shard child (same semantics as [`LaunchConfig`]).
    pub max_restarts: usize,
    /// Supervision/sync poll interval in milliseconds.
    pub poll_ms: u64,
    /// Epoch length for live memory exchange (must match the rest of the
    /// fleet; `None` = exchange off).
    pub exchange_epoch: Option<usize>,
    /// Consecutive failed sync cycles tolerated before the worker gives up
    /// (transient transport errors are retried; a vanished root is fatal
    /// immediately).
    pub sync_error_budget: usize,
    /// Extra environment variables for the shard children.
    pub child_env: Vec<(String, String)>,
}

impl WorkerConfig {
    /// A worker running `subcommand` as manifest row `worker_id`, with
    /// default supervision settings.
    pub fn new<P: Into<PathBuf>, Q: Into<PathBuf>>(
        program: P,
        subcommand: &str,
        run_dir: Q,
        manifest: WorkerManifest,
        worker_id: &str,
    ) -> WorkerConfig {
        WorkerConfig {
            program: program.into(),
            subcommand: subcommand.to_string(),
            passthrough: Vec::new(),
            manifest,
            worker_id: worker_id.to_string(),
            run_dir: run_dir.into(),
            max_restarts: 2,
            poll_ms: 100,
            exchange_epoch: None,
            sync_error_budget: 100,
            child_env: Vec::new(),
        }
    }
}

/// Outcome of a successful [`run_worker`].
#[derive(Debug, Clone)]
pub struct WorkerReport {
    /// Which manifest row ran.
    pub worker_id: String,
    /// Per-shard supervision outcomes (global shard indices).
    pub shards: Vec<ShardOutcome>,
    /// Transport sync cycles executed.
    pub sync_cycles: usize,
}

impl WorkerReport {
    /// Human-readable multi-line summary (the `worker` CLI output).
    pub fn render(&self) -> String {
        let restarts: usize = self.shards.iter().map(|s| s.restarts).sum();
        let mut out = format!(
            "worker {}: {} shard(s) done, {} crash-restart(s), {} sync cycle(s)\n",
            self.worker_id,
            self.shards.len(),
            restarts,
            self.sync_cycles
        );
        for s in &self.shards {
            out.push_str(&format!(
                "  shard {}  {} restart(s)  log {}\n",
                s.index,
                s.restarts,
                s.log.display()
            ));
        }
        out
    }
}

/// Test hook: `KS_TEST_WORKER_SYNC_DELAY_MS=<n>` stretches every worker
/// sync cycle by `n` milliseconds — how the CI `elastic-smoke` job
/// manufactures a heterogeneous fleet with one deliberately slow worker.
fn sync_delay_from_env() -> Duration {
    std::env::var("KS_TEST_WORKER_SYNC_DELAY_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .map_or(Duration::ZERO, Duration::from_millis)
}

/// Test hook for the distributed batteries and the CI `multi-node-smoke`
/// job: with `KS_TEST_WORKER_CRASH_AFTER_SYNCS=<n>` and
/// `KS_TEST_WORKER_CRASH_MARKER=<path>` both set, the worker simulates its
/// whole machine dying after its n-th sync cycle — it hard-kills every
/// shard child and exits 86 — once per `<path>.worker-<id>` marker, so the
/// restarted worker resumes and runs to completion.
struct WorkerCrashHook {
    after: usize,
    marker: PathBuf,
    cycles: usize,
}

impl WorkerCrashHook {
    fn from_env(worker_id: &str) -> Option<WorkerCrashHook> {
        let after: usize = std::env::var("KS_TEST_WORKER_CRASH_AFTER_SYNCS")
            .ok()?
            .parse()
            .ok()?;
        let marker = std::env::var("KS_TEST_WORKER_CRASH_MARKER").ok()?;
        if marker.is_empty() || after == 0 {
            return None;
        }
        Some(WorkerCrashHook {
            after,
            marker: PathBuf::from(format!("{marker}.worker-{worker_id}")),
            cycles: 0,
        })
    }

    fn tick(&mut self, procs: &mut [ShardProc]) {
        self.cycles += 1;
        if self.cycles >= self.after && !self.marker.exists() {
            let _ = std::fs::write(&self.marker, "crashed\n");
            crate::log_warn!(
                "KS_TEST_WORKER_CRASH_AFTER_SYNCS: simulating a dead worker machine after \
                 {} sync cycle(s)",
                self.cycles
            );
            reap_all(procs);
            std::process::exit(86);
        }
    }
}

/// One worker-side transport sync pass: push the shard run dirs and own
/// exchange deltas up, install the fleet's deltas down.
fn worker_sync_cycle(
    pushes: &mut [ShardPush],
    exchange_push: &mut Option<ExchangePush>,
    exchange_pull: &mut Option<ExchangePull>,
    transport: &dyn RunDirTransport,
) -> Result<bool, String> {
    let mut progress = false;
    for push in pushes.iter_mut() {
        progress |= push.cycle(transport)?;
    }
    if let Some(xp) = exchange_push.as_mut() {
        progress |= xp.cycle(transport)?;
    }
    if let Some(xl) = exchange_pull.as_mut() {
        progress |= xl.cycle(transport)?;
    }
    Ok(progress)
}

/// Run this machine's manifest row: spawn and supervise its shard range
/// (with the same crash-restart policy as [`launch`]), publish the shard
/// run dirs through the row's transport, and pull the fleet's exchange
/// deltas down for the local shards to fold. Restart-safe: a rerun resumes
/// the children from their checkpoints and the pushes from the transport's
/// current state. Returns once every shard has finished *and* every byte
/// (including the `complete` markers) is published.
pub fn run_worker(cfg: &WorkerConfig) -> Result<WorkerReport, String> {
    let spec = cfg.manifest.worker(&cfg.worker_id).ok_or_else(|| {
        format!(
            "worker id {:?} is not in the manifest (known: {:?})",
            cfg.worker_id,
            cfg.manifest.worker_ids()
        )
    })?;
    if let Some(0) = cfg.exchange_epoch {
        return Err("--exchange-epoch must be >= 1".to_string());
    }
    std::fs::create_dir_all(&cfg.run_dir)
        .map_err(|e| format!("creating {}: {e}", cfg.run_dir.display()))?;
    if cfg.manifest.is_elastic() {
        let spec = spec.clone();
        return run_worker_elastic(cfg, &spec);
    }
    let transport = spec.transport.build()?;
    let passthrough = worker_passthrough(&cfg.passthrough, spec)?;
    // Zero-copy transports (a shared filesystem) let the children stream
    // straight into the transport root; otherwise they run in local dirs
    // the push engines mirror outward.
    let zero_copy = transport.local_dir("up").is_some();
    crate::log_info!(
        "worker {}: shards {}-{} via {}{}",
        spec.id,
        spec.shard_lo,
        spec.shard_hi,
        transport.describe(),
        if zero_copy { " (zero-copy)" } else { "" }
    );

    let indices: Vec<usize> = spec.shard_indices().collect();
    let mut dirs: Vec<PathBuf> = Vec::new();
    for &i in &indices {
        let dir = transport
            .local_dir(&up_shard_rel(i))
            .unwrap_or_else(|| cfg.run_dir.join(format!("shard-{i}")));
        std::fs::create_dir_all(&dir).map_err(|e| format!("creating {}: {e}", dir.display()))?;
        dirs.push(dir);
    }
    let exchange_dir = match cfg.exchange_epoch {
        Some(_) => {
            let dir = transport
                .local_dir(UP_EXCHANGE)
                .unwrap_or_else(|| cfg.run_dir.join("exchange"));
            std::fs::create_dir_all(&dir)
                .map_err(|e| format!("creating {}: {e}", dir.display()))?;
            Some(dir)
        }
        None => None,
    };

    let mut pushes: Vec<ShardPush> = Vec::new();
    if !zero_copy {
        for (&i, dir) in indices.iter().zip(&dirs) {
            pushes.push(ShardPush::new(dir, i, transport.as_ref())?);
        }
    }
    let mut exchange_push = match (&exchange_dir, zero_copy) {
        (Some(dir), false) => Some(ExchangePush::new(dir, indices.clone())),
        _ => None,
    };
    let mut exchange_pull = exchange_dir.as_ref().map(|dir| ExchangePull::new(dir));

    let child_params = |i: usize, dir: &Path| ChildParams {
        program: cfg.program.clone(),
        subcommand: cfg.subcommand.clone(),
        passthrough: passthrough.clone(),
        dir: dir.to_path_buf(),
        log_path: cfg.run_dir.join(format!("shard-{i}.log")),
        total_shards: cfg.manifest.total_shards,
        index: i,
        batch_mode: false,
        exchange: exchange_dir
            .as_ref()
            .and_then(|d| cfg.exchange_epoch.map(|e| (d.clone(), e))),
        env: cfg.child_env.clone(),
    };

    let mut procs: Vec<ShardProc> = Vec::new();
    for (&i, dir) in indices.iter().zip(&dirs) {
        procs.push(ShardProc {
            index: i,
            child: Some(spawn_child(&child_params(i, dir), false)?),
            restarts: 0,
            tempfail_restarts: 0,
            done: false,
        });
    }

    let mut crash_hook = WorkerCrashHook::from_env(&cfg.worker_id);
    let sync_delay = sync_delay_from_env();
    let mut sync_cycles = 0usize;
    let mut consecutive_sync_errors = 0usize;
    let mut post_exit_cycles = 0usize;
    let mut last_sync_ok = false;
    {
        let guard = ReapOnDrop(&mut procs);
        loop {
            let all_done = poll_procs(&mut *guard.0, cfg.max_restarts, &cfg.run_dir, &mut |i| {
                let pos = indices.iter().position(|&x| x == i).ok_or_else(|| {
                    format!("internal: asked to respawn shard {i}, which this worker does not own")
                })?;
                spawn_child(&child_params(i, &dirs[pos]), true)
            })?;
            // A vanished transport root is immediately fatal; transient
            // sync failures are warned about and retried within a budget.
            transport.check()?;
            let sync = worker_sync_cycle(
                &mut pushes,
                &mut exchange_push,
                &mut exchange_pull,
                transport.as_ref(),
            );
            sync_cycles += 1;
            match sync {
                Ok(_) => {
                    consecutive_sync_errors = 0;
                    last_sync_ok = true;
                }
                Err(e) => {
                    consecutive_sync_errors += 1;
                    last_sync_ok = false;
                    if consecutive_sync_errors > cfg.sync_error_budget {
                        return Err(format!(
                            "sync with {} failed {consecutive_sync_errors} cycle(s) in a \
                             row; giving up ({e})",
                            transport.describe()
                        ));
                    }
                    crate::log_warn!("worker {}: sync cycle failed (will retry): {e}", spec.id);
                }
            }
            if let Some(hook) = crash_hook.as_mut() {
                hook.tick(&mut *guard.0);
            }
            if all_done {
                // Children exited cleanly (each wrote its `complete`
                // marker); keep syncing until every byte is published. The
                // last cycle must have *succeeded* in full: a transient
                // failure after the `complete` markers landed could
                // otherwise leave a final exchange delta unpublished,
                // starving peer machines' shards at their epoch boundary.
                if last_sync_ok && pushes.iter().all(|p| p.is_complete()) {
                    break;
                }
                post_exit_cycles += 1;
                if post_exit_cycles > cfg.sync_error_budget {
                    return Err(format!(
                        "shard children exited but their run dirs never finished \
                         publishing through {} — is a child missing its `complete` \
                         marker?",
                        transport.describe()
                    ));
                }
            }
            std::thread::sleep(Duration::from_millis(cfg.poll_ms.max(1)) + sync_delay);
        }
    }

    Ok(WorkerReport {
        worker_id: spec.id.clone(),
        shards: procs
            .iter()
            .enumerate()
            .map(|(pos, s)| ShardOutcome {
                index: s.index,
                dir: dirs[pos].clone(),
                log: cfg.run_dir.join(format!("shard-{}.log", s.index)),
                restarts: s.restarts,
            })
            .collect(),
        sync_cycles,
    })
}

/// The elastic counterpart of [`run_worker`]: instead of a fixed shard
/// range, claim the lowest claimable lease batch, run it as one child, and
/// repeat until the whole lease board is done. One batch runs at a time —
/// intra-machine parallelism belongs to the batch child's own `--workers`,
/// not to racing lease claims against yourself.
///
/// Liveness is the *progress counter*: every sync cycle that advanced the
/// published checkpoint re-publishes the held lease with the new counter.
/// A worker that dies mid-batch simply stops advancing it; the coordinator
/// notices, publishes the `.expired` re-dispatch marker, and a surviving
/// worker re-claims the batch. The re-claimer recomputes the batch's
/// deterministic bytes from scratch and its push waits below the cover a
/// dead attempt already published, so every published byte stays
/// bit-identical no matter how many attempts a batch took.
fn run_worker_elastic(cfg: &WorkerConfig, spec: &WorkerSpec) -> Result<WorkerReport, String> {
    let total_batches = cfg.manifest.total_batches;
    let lease_spec = cfg.manifest.lease.as_ref().ok_or_else(|| {
        "internal: elastic worker started from a manifest with no lease transport".to_string()
    })?;
    let leases = lease_spec.build().map_err(|e| format!("lease transport: {e}"))?;
    let transport = spec.transport.build()?;
    let passthrough = worker_passthrough(&cfg.passthrough, spec)?;
    // Elastic children always run in local dirs mirrored outward by a push
    // engine — never zero-copy — so a re-dispatched batch's recompute
    // happens privately and only newline-complete deterministic bytes ever
    // reach the transport.
    crate::log_info!(
        "worker {}: elastic, {} batch(es) on lease board {} via {}",
        spec.id,
        total_batches,
        leases.describe(),
        transport.describe()
    );

    let exchange_dir = match cfg.exchange_epoch {
        Some(_) => {
            let dir = cfg.run_dir.join("exchange");
            std::fs::create_dir_all(&dir)
                .map_err(|e| format!("creating {}: {e}", dir.display()))?;
            Some(dir)
        }
        None => None,
    };
    let mut exchange_pull = exchange_dir.as_ref().map(|dir| ExchangePull::new(dir));

    let mut crash_hook = WorkerCrashHook::from_env(&cfg.worker_id);
    let sync_delay = sync_delay_from_env();
    let mut sync_cycles = 0usize;
    let mut outcomes: Vec<ShardOutcome> = Vec::new();

    'claims: loop {
        leases.check()?;
        let board = read_lease_board(leases.as_ref(), total_batches)?;
        if board.iter().all(|b| b.done) {
            break 'claims;
        }
        let Some(mut lease) = claim_next_batch(leases.as_ref(), &board, &cfg.worker_id)? else {
            // Everything is held or done; poll — a straggler's lease may
            // yet expire and come back claimable.
            std::thread::sleep(Duration::from_millis(cfg.poll_ms.max(1)) + sync_delay);
            continue 'claims;
        };
        crate::log_info!(
            "worker {}: claimed batch {} (attempt {})",
            spec.id,
            lease.batch,
            lease.attempt
        );

        let dir = cfg.run_dir.join(format!("batch-{}", lease.batch));
        std::fs::create_dir_all(&dir)
            .map_err(|e| format!("creating {}: {e}", dir.display()))?;
        transport.check()?;
        let mut push = ShardPush::new_batch(&dir, lease.batch, transport.as_ref())?;
        let mut exchange_push = exchange_dir
            .as_ref()
            .map(|d| ExchangePush::new(d, vec![lease.batch]));

        let params = ChildParams {
            program: cfg.program.clone(),
            subcommand: cfg.subcommand.clone(),
            passthrough: passthrough.clone(),
            dir: dir.clone(),
            log_path: cfg.run_dir.join(format!("batch-{}.log", lease.batch)),
            total_shards: total_batches,
            index: lease.batch,
            batch_mode: true,
            exchange: exchange_dir
                .as_ref()
                .and_then(|d| cfg.exchange_epoch.map(|e| (d.clone(), e))),
            env: cfg.child_env.clone(),
        };
        let mut procs = vec![ShardProc {
            index: lease.batch,
            child: Some(spawn_child(&params, lease.attempt > 0)?),
            restarts: 0,
            tempfail_restarts: 0,
            done: false,
        }];
        let mut consecutive_sync_errors = 0usize;
        let mut post_exit_cycles = 0usize;
        let mut last_sync_ok = false;
        {
            let guard = ReapOnDrop(&mut procs);
            loop {
                let s = &mut guard.0[0];
                if !s.done {
                    let child = s.child.as_mut().ok_or_else(|| {
                        format!("internal: batch {} has no child to wait on", s.index)
                    })?;
                    match child.try_wait() {
                        Ok(None) => {}
                        Ok(Some(status)) if status.success() => {
                            s.child = None;
                            s.done = true;
                        }
                        Ok(Some(status)) if status.code() == Some(EXCHANGE_TIMEOUT_EXIT) => {
                            s.child = None;
                            if s.tempfail_restarts >= TEMPFAIL_RESTART_CAP {
                                return Err(format!(
                                    "batch {} is starved of exchange deltas: {} restartable \
                                     timeout exit(s) without the peer delta appearing; see {}",
                                    s.index,
                                    s.tempfail_restarts,
                                    params.log_path.display()
                                ));
                            }
                            s.tempfail_restarts += 1;
                            crate::log_warn!(
                                "batch {} hit a restartable exchange-wait timeout; \
                                 relaunching ({}/{} restartable exits)",
                                s.index,
                                s.tempfail_restarts,
                                TEMPFAIL_RESTART_CAP
                            );
                            s.child = Some(spawn_child(&params, true)?);
                        }
                        Ok(Some(status)) => {
                            s.child = None;
                            if s.restarts >= cfg.max_restarts {
                                return Err(format!(
                                    "batch {} failed with {status} after {} restart(s); see {}",
                                    s.index,
                                    s.restarts,
                                    params.log_path.display()
                                ));
                            }
                            s.restarts += 1;
                            crate::log_warn!(
                                "batch {} exited with {status}; restarting ({}/{})",
                                s.index,
                                s.restarts,
                                cfg.max_restarts
                            );
                            s.child = Some(spawn_child(&params, true)?);
                        }
                        Err(e) => return Err(format!("waiting on batch {}: {e}", s.index)),
                    }
                }
                let child_done = guard.0[0].done;

                // A vanished root (transport or lease board) is immediately
                // fatal; transient sync failures retry within the budget.
                transport.check()?;
                leases.check()?;
                let sync = (|| -> Result<(), String> {
                    push.cycle(transport.as_ref())?;
                    if let Some(xp) = exchange_push.as_mut() {
                        xp.cycle(transport.as_ref())?;
                    }
                    if let Some(xl) = exchange_pull.as_mut() {
                        xl.cycle(transport.as_ref())?;
                    }
                    // Heartbeat: the lease carries the monotone published
                    // counter, never a timestamp — a worker only looks
                    // alive while its checkpoint actually grows.
                    if push.results_pushed() != lease.progress {
                        lease.progress = push.results_pushed();
                        leases.publish(&lease.rel(), &lease.to_bytes())?;
                    }
                    Ok(())
                })();
                sync_cycles += 1;
                match sync {
                    Ok(()) => {
                        consecutive_sync_errors = 0;
                        last_sync_ok = true;
                    }
                    Err(e) => {
                        consecutive_sync_errors += 1;
                        last_sync_ok = false;
                        if consecutive_sync_errors > cfg.sync_error_budget {
                            return Err(format!(
                                "sync with {} failed {consecutive_sync_errors} cycle(s) in \
                                 a row; giving up ({e})",
                                transport.describe()
                            ));
                        }
                        crate::log_warn!(
                            "worker {}: sync cycle failed (will retry): {e}",
                            spec.id
                        );
                    }
                }
                if let Some(hook) = crash_hook.as_mut() {
                    hook.tick(&mut *guard.0);
                }
                if child_done {
                    if last_sync_ok && push.is_complete() {
                        break;
                    }
                    post_exit_cycles += 1;
                    if post_exit_cycles > cfg.sync_error_budget {
                        return Err(format!(
                            "batch {} finished but never finished publishing through {} — \
                             is the child missing its `complete` marker?",
                            lease.batch,
                            transport.describe()
                        ));
                    }
                }
                std::thread::sleep(Duration::from_millis(cfg.poll_ms.max(1)) + sync_delay);
            }
        }
        // Every byte (including `complete`) is published; mark the lease
        // done. A batch finished late — after being expired and re-claimed
        // elsewhere — marks done too: the bytes are identical, the merge
        // deduplicates, and the board converges either way.
        lease.progress = push.results_pushed();
        lease.done = true;
        leases.publish(&lease.rel(), &lease.to_bytes())?;
        crate::log_info!(
            "worker {}: batch {} complete ({} byte(s) published)",
            spec.id,
            lease.batch,
            lease.progress
        );
        outcomes.push(ShardOutcome {
            index: lease.batch,
            dir,
            log: params.log_path.clone(),
            restarts: procs[0].restarts,
        });
    }

    Ok(WorkerReport {
        worker_id: spec.id.clone(),
        shards: outcomes,
        sync_cycles,
    })
}

// ------------------------------------------------------------------------
// Cross-machine: the coordinator side
// ------------------------------------------------------------------------

/// What the fleet coordinator supervises: the manifest's workers, pulled
/// into mirrors under `run_dir` and merged there.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// The validated fleet manifest.
    pub manifest: WorkerManifest,
    /// Output run dir: per-worker mirrors stream into
    /// `<run_dir>/mirror/shard-<i>`, the merge lands in `<run_dir>`.
    pub run_dir: PathBuf,
    /// Pull/relay poll interval in milliseconds.
    pub poll_ms: u64,
    /// With no progress from any worker for this long, the launch fails
    /// with a per-worker status instead of hanging forever (workers that
    /// die stay down until their machine restarts them).
    pub stall_timeout_ms: u64,
    /// Elastic fleets: a held lease whose progress counter has not
    /// advanced for this long is expired (re-dispatch marker published) so
    /// a surviving worker can re-claim the batch. Compared against the
    /// counter in the heartbeat body — never a file mtime, which clock
    /// skew and coarse filesystem timestamps defeat.
    pub lease_timeout_ms: u64,
    /// Consecutive failed sync cycles tolerated before giving up.
    pub sync_error_budget: usize,
}

impl FleetConfig {
    /// A coordinator for `manifest` merging into `run_dir`, with default
    /// supervision settings.
    pub fn new<P: Into<PathBuf>>(manifest: WorkerManifest, run_dir: P) -> FleetConfig {
        FleetConfig {
            manifest,
            run_dir: run_dir.into(),
            poll_ms: 200,
            stall_timeout_ms: 600_000,
            lease_timeout_ms: 60_000,
            sync_error_budget: 100,
        }
    }
}

/// One worker's row in a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct FleetWorkerSummary {
    /// Worker id.
    pub id: String,
    /// Global shard indices the worker ran.
    pub shards: Vec<usize>,
    /// Transport endpoint description.
    pub transport: String,
    /// Whether the zero-copy path was used (no mirror copies).
    pub zero_copy: bool,
}

/// Outcome of a successful [`launch_workers`].
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Per-worker summaries, in manifest order.
    pub workers: Vec<FleetWorkerSummary>,
    /// The final streaming-merge report.
    pub merge: MergeReport,
}

impl FleetReport {
    /// Human-readable multi-line summary (the fleet `launch` CLI output).
    pub fn render(&self) -> String {
        let mut out = format!(
            "coordinated {} worker(s) over run-dir transports\n",
            self.workers.len()
        );
        for w in &self.workers {
            out.push_str(&format!(
                "  {:<12} shards {:?}  {}{}\n",
                w.id,
                w.shards,
                w.transport,
                if w.zero_copy { "  (zero-copy)" } else { "" }
            ));
        }
        out.push_str(&self.merge.render());
        out
    }
}

/// One coordinator-side transport sync pass: tail-pull every worker's
/// shard mirrors and relay the fleet's exchange deltas.
fn fleet_sync_cycle(
    pulls: &mut [Option<ShardPull>],
    owner: &[usize],
    transports: &[Box<dyn RunDirTransport>],
    hub: &mut ExchangeHub,
    workers: &[WorkerSpec],
) -> Result<bool, String> {
    let mut progress = false;
    for (i, pull) in pulls.iter_mut().enumerate() {
        if let Some(p) = pull {
            progress |= p.cycle(transports[owner[i]].as_ref())?;
        }
    }
    progress |= hub.cycle(workers, transports)?;
    Ok(progress)
}

/// Supervise a cross-machine launch: tail-sync every worker's published
/// run dirs into local mirrors, feed them to the streaming merge, relay
/// exchange deltas between workers mid-run, and finalize once every
/// worker's slice is complete — byte-identical to a single-process run of
/// the same matrix. The coordinator spawns nothing: workers are started
/// (and, if their machines die, restarted) out of band with the `worker`
/// subcommand, and a restarted coordinator resumes its mirrors in place.
pub fn launch_workers(cfg: &FleetConfig) -> Result<FleetReport, String> {
    cfg.manifest.validate()?;
    std::fs::create_dir_all(&cfg.run_dir)
        .map_err(|e| format!("creating {}: {e}", cfg.run_dir.display()))?;
    let out_rd = RunDir::open(&cfg.run_dir)
        .map_err(|e| format!("opening {}: {e}", cfg.run_dir.display()))?;
    if out_rd.has_results() {
        return Err(format!(
            "{} already holds merged results; pick a fresh --run-dir",
            cfg.run_dir.display()
        ));
    }
    if cfg.manifest.is_elastic() {
        return launch_workers_elastic(cfg, out_rd);
    }

    let total = cfg.manifest.total_shards;
    let mut transports: Vec<Box<dyn RunDirTransport>> = Vec::new();
    for w in &cfg.manifest.workers {
        transports.push(w.transport.build().map_err(|e| format!("worker {:?}: {e}", w.id))?);
    }
    // Global shard index -> (owning worker, mirror dir, pull engine). Pull
    // is None on the zero-copy path, where the mirror *is* the transport's
    // directory and the worker's children write it directly.
    let mut owner: Vec<usize> = vec![0; total];
    let mut mirror_dirs: Vec<PathBuf> = vec![PathBuf::new(); total];
    let mut pulls: Vec<Option<ShardPull>> = (0..total).map(|_| None).collect();
    for (wi, w) in cfg.manifest.workers.iter().enumerate() {
        for i in w.shard_indices() {
            owner[i] = wi;
            match transports[wi].local_dir(&up_shard_rel(i)) {
                Some(dir) => {
                    std::fs::create_dir_all(&dir)
                        .map_err(|e| format!("creating {}: {e}", dir.display()))?;
                    mirror_dirs[i] = dir;
                }
                None => {
                    let dir = cfg.run_dir.join("mirror").join(format!("shard-{i}"));
                    pulls[i] = Some(ShardPull::new(&dir, i)?);
                    mirror_dirs[i] = dir;
                }
            }
        }
    }

    let mut watcher = MergeWatcher::new(&cfg.run_dir, &mirror_dirs)?;
    let mut hub = ExchangeHub::new();
    let mut last_cells = usize::MAX;
    let mut last_progress = Instant::now();
    let mut consecutive_sync_errors = 0usize;
    loop {
        for (wi, t) in transports.iter().enumerate() {
            t.check()
                .map_err(|e| format!("worker {:?}: {e}", cfg.manifest.workers[wi].id))?;
        }
        let sync = fleet_sync_cycle(
            &mut pulls,
            &owner,
            &transports,
            &mut hub,
            &cfg.manifest.workers,
        );
        let mut progress = false;
        match sync {
            Ok(p) => {
                progress |= p;
                consecutive_sync_errors = 0;
            }
            Err(e) => {
                consecutive_sync_errors += 1;
                if consecutive_sync_errors > cfg.sync_error_budget {
                    return Err(format!(
                        "worker sync failed {consecutive_sync_errors} cycle(s) in a row; \
                         giving up ({e})"
                    ));
                }
                crate::log_warn!("launch: sync cycle failed (will retry): {e}");
            }
        }
        let status = watcher.poll()?;
        if status.cells != last_cells {
            last_cells = status.cells;
            progress = true;
            crate::log_info!("launch: {}", status.render());
        }
        if status.all_complete() {
            break;
        }
        if progress {
            last_progress = Instant::now();
        } else if last_progress.elapsed() >= Duration::from_millis(cfg.stall_timeout_ms) {
            let stalled: Vec<String> = cfg
                .manifest
                .workers
                .iter()
                .enumerate()
                .filter(|(wi, _)| {
                    (0..total).any(|i| owner[i] == *wi && !status.complete[i])
                })
                .map(|(_, w)| w.id.clone())
                .collect();
            return Err(format!(
                "no progress for {}ms waiting on worker(s) {stalled:?} — are their \
                 `worker` processes running? ({})",
                cfg.stall_timeout_ms,
                status.render()
            ));
        }
        std::thread::sleep(Duration::from_millis(cfg.poll_ms.max(1)));
    }

    let merge = watcher.finalize()?;
    out_rd
        .mark_complete()
        .map_err(|e| format!("writing completion marker: {e}"))?;
    Ok(FleetReport {
        workers: cfg
            .manifest
            .workers
            .iter()
            .enumerate()
            .map(|(wi, w)| FleetWorkerSummary {
                id: w.id.clone(),
                shards: w.shard_indices().collect(),
                transport: transports[wi].describe(),
                zero_copy: transports[wi].local_dir("up").is_some(),
            })
            .collect(),
        merge,
    })
}

/// The elastic counterpart of [`launch_workers`]: supervise a lease-based
/// fleet. The coordinator spawns nothing and assigns nothing — workers
/// claim batches off the shared lease board themselves. Its jobs are:
///
/// 1. **Re-dispatch stragglers.** A held lease whose progress counter
///    stops advancing for [`FleetConfig::lease_timeout_ms`] gets its
///    `.expired` marker published, making the batch claimable again.
///    Liveness is judged purely on the counter in the heartbeat body —
///    mtimes are never consulted.
/// 2. **Mirror every attempt.** Each `up/batch-<k>` that appears on any
///    worker's transport is tail-pulled into its own local mirror and fed
///    to the streaming merge as it materializes. A batch re-dispatched
///    across workers yields two mirrors with bit-identical (one possibly
///    truncated) content; the merge deduplicates them.
/// 3. **Relay exchange deltas** between all workers (route-all: ownership
///    lives in leases, not manifest ranges).
///
/// Finalizes once every batch is done on the board and fully mirrored from
/// at least one attempt — byte-identical to a single-process run.
fn launch_workers_elastic(cfg: &FleetConfig, out_rd: RunDir) -> Result<FleetReport, String> {
    let total = cfg.manifest.total_batches;
    let lease_spec = cfg.manifest.lease.as_ref().ok_or_else(|| {
        "internal: elastic coordinator started from a manifest with no lease transport"
            .to_string()
    })?;
    let leases = lease_spec.build().map_err(|e| format!("lease transport: {e}"))?;
    let mut transports: Vec<Box<dyn RunDirTransport>> = Vec::new();
    for w in &cfg.manifest.workers {
        transports.push(w.transport.build().map_err(|e| format!("worker {:?}: {e}", w.id))?);
    }
    crate::log_info!(
        "launch: elastic, {} batch(es), {} worker(s), lease board {}",
        total,
        transports.len(),
        leases.describe()
    );

    let mut watcher = MergeWatcher::new_dynamic(&cfg.run_dir)?;
    let mut hub = ExchangeHub::new_route_all();
    // One mirror per (worker, batch) attempt stream seen on a transport.
    let mut pulls: BTreeMap<(usize, usize), ShardPull> = BTreeMap::new();
    let mut mirror_dirs: BTreeMap<(usize, usize), PathBuf> = BTreeMap::new();
    let mut watched: BTreeMap<(usize, usize), bool> = BTreeMap::new();
    // Liveness per (batch, attempt): last counter value and when it last
    // advanced (by our clock — the counter itself carries no time).
    let mut counters: BTreeMap<(usize, usize), (u64, Instant)> = BTreeMap::new();
    let mut board_fingerprint: Vec<(usize, bool, bool, u64)> = Vec::new();
    let mut last_cells = usize::MAX;
    let mut last_progress = Instant::now();
    let mut consecutive_sync_errors = 0usize;
    loop {
        leases.check()?;
        for (wi, t) in transports.iter().enumerate() {
            t.check()
                .map_err(|e| format!("worker {:?}: {e}", cfg.manifest.workers[wi].id))?;
        }
        let mut progress = false;

        let sync = (|| -> Result<bool, String> {
            let mut moved = false;
            let board = read_lease_board(leases.as_ref(), total)?;
            let fingerprint: Vec<(usize, bool, bool, u64)> = board
                .iter()
                .map(|s| {
                    (
                        s.attempts,
                        s.done,
                        s.latest_expired,
                        s.latest.as_ref().map_or(0, |l| l.progress),
                    )
                })
                .collect();
            if fingerprint != board_fingerprint {
                board_fingerprint = fingerprint;
                moved = true;
            }

            // Straggler re-dispatch: expire held leases whose counter
            // stalled for lease_timeout_ms.
            for st in &board {
                if st.done || st.attempts == 0 || st.latest_expired {
                    continue;
                }
                let Some(l) = &st.latest else { continue };
                let attempt = st.attempts - 1;
                let entry = counters
                    .entry((st.batch, attempt))
                    .or_insert((l.progress, Instant::now()));
                if l.progress > entry.0 {
                    *entry = (l.progress, Instant::now());
                } else if entry.1.elapsed() >= Duration::from_millis(cfg.lease_timeout_ms) {
                    if expire_lease(leases.as_ref(), st.batch, attempt)? {
                        crate::log_warn!(
                            "launch: batch {} attempt {} (worker {:?}) stalled at {} \
                             byte(s) for {}ms; expired for re-dispatch",
                            st.batch,
                            attempt,
                            l.worker,
                            l.progress,
                            cfg.lease_timeout_ms
                        );
                        moved = true;
                    }
                }
            }

            // Discover new attempt streams and tail-pull every known one.
            for (wi, t) in transports.iter().enumerate() {
                for name in t.list_dirs("up")? {
                    let Some(batch) = parse_up_batch_name(&name) else { continue };
                    if batch >= total {
                        return Err(format!(
                            "worker {:?} publishes {name} but the manifest declares only \
                             {total} batch(es) — its transport root belongs to a \
                             different run",
                            cfg.manifest.workers[wi].id
                        ));
                    }
                    if !mirror_dirs.contains_key(&(wi, batch)) {
                        let dir = cfg
                            .run_dir
                            .join("mirror")
                            .join(format!("{}-batch-{batch}", cfg.manifest.workers[wi].id));
                        pulls.insert((wi, batch), ShardPull::new_batch(&dir, batch)?);
                        mirror_dirs.insert((wi, batch), dir);
                        watched.insert((wi, batch), false);
                    }
                }
            }
            for (&(wi, _), pull) in pulls.iter_mut() {
                moved |= pull.cycle(transports[wi].as_ref())?;
            }
            // A mirror joins the merge once it *is* a run dir (its
            // manifest landed); a stream that died before pushing one
            // never becomes an input.
            for (key, seen) in watched.iter_mut() {
                if !*seen && mirror_dirs[key].join("manifest.json").exists() {
                    watcher.add_input(&mirror_dirs[key]);
                    *seen = true;
                }
            }
            moved |= hub.cycle(&cfg.manifest.workers, &transports)?;
            Ok(moved)
        })();
        match sync {
            Ok(p) => {
                progress |= p;
                consecutive_sync_errors = 0;
            }
            Err(e) => {
                consecutive_sync_errors += 1;
                if consecutive_sync_errors > cfg.sync_error_budget {
                    return Err(format!(
                        "worker sync failed {consecutive_sync_errors} cycle(s) in a row; \
                         giving up ({e})"
                    ));
                }
                crate::log_warn!("launch: sync cycle failed (will retry): {e}");
            }
        }

        let status = watcher.poll()?;
        if status.cells != last_cells {
            last_cells = status.cells;
            progress = true;
            crate::log_info!("launch: {}", status.render());
        }
        // Done when the board says every batch finished somewhere AND at
        // least one attempt stream of each batch is fully mirrored.
        let board_done = !board_fingerprint.is_empty()
            && board_fingerprint.iter().all(|&(_, done, _, _)| done);
        if board_done
            && (0..total).all(|batch| {
                pulls
                    .iter()
                    .any(|(&(_, b), pull)| b == batch && pull.is_complete())
            })
        {
            break;
        }
        if progress {
            last_progress = Instant::now();
        } else if last_progress.elapsed() >= Duration::from_millis(cfg.stall_timeout_ms) {
            return Err(format!(
                "no progress for {}ms waiting on the elastic fleet — are the `worker` \
                 processes running? ({})",
                cfg.stall_timeout_ms,
                status.render()
            ));
        }
        std::thread::sleep(Duration::from_millis(cfg.poll_ms.max(1)));
    }

    let merge = watcher.finalize()?;
    out_rd
        .mark_complete()
        .map_err(|e| format!("writing completion marker: {e}"))?;
    // Attribute each batch to the worker whose (latest) attempt completed
    // it, for the human-readable report.
    let final_board = read_lease_board(leases.as_ref(), total)?;
    Ok(FleetReport {
        workers: cfg
            .manifest
            .workers
            .iter()
            .enumerate()
            .map(|(wi, w)| FleetWorkerSummary {
                id: w.id.clone(),
                shards: final_board
                    .iter()
                    .filter(|s| {
                        s.done && s.latest.as_ref().is_some_and(|l| l.worker == w.id)
                    })
                    .map(|s| s.batch)
                    .collect(),
                transport: transports[wi].describe(),
                zero_copy: false,
            })
            .collect(),
        merge,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::transport::{TransportKind, TransportSpec};

    fn spec(device: Option<&str>) -> WorkerSpec {
        WorkerSpec {
            id: "w0".to_string(),
            shard_lo: 0,
            shard_hi: 0,
            transport: TransportSpec {
                kind: TransportKind::MirrorDir,
                root: PathBuf::from("/tmp/unused"),
            },
            device: device.map(str::to_string),
        }
    }

    #[test]
    fn worker_passthrough_forwards_the_manifest_device() {
        let base = vec!["--level".to_string(), "1".to_string()];
        let out = worker_passthrough(&base, &spec(None)).unwrap();
        assert_eq!(out, base);
        let out = worker_passthrough(&base, &spec(Some("tpu-like"))).unwrap();
        assert_eq!(out, vec!["--level", "1", "--device", "tpu-like"]);
        // A job-spec passthrough is one sealed identity artifact: the
        // `worker` CLI already folded the manifest device into the spec,
        // so nothing may be appended next to it.
        let sealed = vec!["--job-spec".to_string(), "/tmp/spec.json".to_string()];
        let out = worker_passthrough(&sealed, &spec(Some("tpu-like"))).unwrap();
        assert_eq!(out, sealed);
    }

    #[test]
    fn worker_passthrough_refuses_a_conflicting_launch_wide_device() {
        let base = vec!["--device".to_string(), "a100-like".to_string()];
        let err = worker_passthrough(&base, &spec(Some("tpu-like"))).unwrap_err();
        assert!(err.contains("--device"), "{err}");
        assert!(err.contains("tpu-like"), "{err}");
        // No manifest device: the launch-wide flag alone is fine.
        assert_eq!(worker_passthrough(&base, &spec(None)).unwrap(), base);
    }
}
