//! Shard launcher: one command that runs a whole distributed suite.
//!
//! `launch` replaces the hand-run N-process + `merge` dance: it spawns
//! `--shards N` child processes of this very binary (std::process only —
//! nothing to install), one per shard of the cell matrix, each streaming
//! to `<run-dir>/shard-<i>`; monitors them; restarts a crashed child with
//! `--resume` (children are always spawned resumable, so a restart picks
//! up exactly at the checkpointed cells); follows the shard checkpoints
//! live through [`MergeWatcher`]; and finalizes the streaming merge into
//! `<run-dir>` itself once every child has exited cleanly. The merged
//! output is byte-identical to a single-process run of the same matrix —
//! the `tests/launcher.rs` battery and the CI `launch-smoke` job (which
//! force-kills a child mid-run) pin that down.
//!
//! With [`LaunchConfig::exchange_epoch`] set, children run with epoch-based
//! live memory exchange through `<run-dir>/exchange` (see
//! `coordinator::scheduler` and `docs/memory-formats.md`): late shards
//! retrieve against skills learned anywhere in the fleet, and the result
//! is still a pure function of (matrix, base memory, epoch length) —
//! byte-identical to a `--shards 1` launch with the same epoch length.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use super::checkpoint::RunDir;
use super::merge::{MergeReport, MergeWatcher};

/// What to launch and how to supervise it.
#[derive(Debug, Clone)]
pub struct LaunchConfig {
    /// Binary to spawn — normally `std::env::current_exe()`.
    pub program: PathBuf,
    /// Subcommand the children run (`suite`, `table1`, …); it must accept
    /// `--run-dir/--shards/--shard-index/--resume`.
    pub subcommand: String,
    /// Flags forwarded verbatim to every child (strategy, level, seeds, …).
    pub passthrough: Vec<String>,
    /// Parent directory: shard `i` streams to `<run_dir>/shard-<i>`, child
    /// logs go to `<run_dir>/shard-<i>.log`, and the final merge lands in
    /// `<run_dir>` itself.
    pub run_dir: PathBuf,
    /// Number of shard processes to run (>= 1).
    pub shards: usize,
    /// Crash budget per shard: a child that exits non-zero is relaunched
    /// (with `--resume`) at most this many times before the launch fails.
    pub max_restarts: usize,
    /// Supervision poll interval in milliseconds.
    pub poll_ms: u64,
    /// Enable live memory exchange with this epoch length (cells); the
    /// exchange dir is `<run_dir>/exchange`.
    pub exchange_epoch: Option<usize>,
    /// Extra environment variables for the children (used by the crash-test
    /// hook in CI and tests).
    pub child_env: Vec<(String, String)>,
}

impl LaunchConfig {
    /// A launch of `shards` children of `program` running `subcommand`
    /// under `run_dir`, with default supervision settings.
    pub fn new<P: Into<PathBuf>, Q: Into<PathBuf>>(
        program: P,
        subcommand: &str,
        run_dir: Q,
        shards: usize,
    ) -> LaunchConfig {
        LaunchConfig {
            program: program.into(),
            subcommand: subcommand.to_string(),
            passthrough: Vec::new(),
            run_dir: run_dir.into(),
            shards,
            max_restarts: 2,
            poll_ms: 50,
            exchange_epoch: None,
            child_env: Vec::new(),
        }
    }
}

/// One shard's supervision outcome.
#[derive(Debug, Clone)]
pub struct ShardOutcome {
    /// Shard index.
    pub index: usize,
    /// The shard's run directory.
    pub dir: PathBuf,
    /// The shard's captured stdout/stderr log.
    pub log: PathBuf,
    /// Times the child was relaunched after a non-zero exit.
    pub restarts: usize,
}

/// Outcome of a successful [`launch`].
#[derive(Debug, Clone)]
pub struct LaunchReport {
    /// Per-shard supervision outcomes.
    pub shards: Vec<ShardOutcome>,
    /// The final streaming-merge report.
    pub merge: MergeReport,
}

impl LaunchReport {
    /// Human-readable multi-line summary (the `launch` CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let restarts: usize = self.shards.iter().map(|s| s.restarts).sum();
        out.push_str(&format!(
            "launched {} shard(s), {} crash-restart(s)\n",
            self.shards.len(),
            restarts
        ));
        for s in &self.shards {
            out.push_str(&format!(
                "  shard {}  {} restart(s)  log {}\n",
                s.index,
                s.restarts,
                s.log.display()
            ));
        }
        out.push_str(&self.merge.render());
        out
    }
}

/// The run directory shard `i` of a launch streams to.
pub fn shard_dir(run_dir: &Path, index: usize) -> PathBuf {
    run_dir.join(format!("shard-{index}"))
}

/// One supervised child.
struct ShardProc {
    index: usize,
    child: Option<Child>,
    restarts: usize,
    done: bool,
}

/// Kills every still-running child on scope exit, so an error return (or a
/// panic) never leaks orphan shard processes.
struct ReapOnDrop<'a>(&'a mut Vec<ShardProc>);

impl Drop for ReapOnDrop<'_> {
    fn drop(&mut self) {
        for s in self.0.iter_mut() {
            if let Some(child) = s.child.as_mut() {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }
}

fn spawn_shard(cfg: &LaunchConfig, index: usize, resume_note: bool) -> Result<Child, String> {
    let dir = shard_dir(&cfg.run_dir, index);
    let log_path = cfg.run_dir.join(format!("shard-{index}.log"));
    let log = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&log_path)
        .map_err(|e| format!("opening {}: {e}", log_path.display()))?;
    let log_err = log
        .try_clone()
        .map_err(|e| format!("opening {}: {e}", log_path.display()))?;
    let mut cmd = Command::new(&cfg.program);
    cmd.arg(&cfg.subcommand)
        .args(&cfg.passthrough)
        .arg("--run-dir")
        .arg(&dir)
        .arg("--shards")
        .arg(cfg.shards.to_string())
        .arg("--shard-index")
        .arg(index.to_string())
        // Children are always resumable: the first run of a fresh dir is a
        // no-op resume, and a crash-restart picks up at the checkpoint.
        .arg("--resume");
    if let Some(epoch) = cfg.exchange_epoch {
        cmd.arg("--exchange-dir")
            .arg(cfg.run_dir.join("exchange"))
            .arg("--exchange-epoch")
            .arg(epoch.to_string());
    }
    for (k, v) in &cfg.child_env {
        cmd.env(k, v);
    }
    cmd.stdin(Stdio::null()).stdout(log).stderr(log_err);
    let child = cmd
        .spawn()
        .map_err(|e| format!("spawning shard {index} ({}): {e}", cfg.program.display()))?;
    if resume_note {
        crate::log_warn!("shard {index}: relaunched with --resume (pid {})", child.id());
    } else {
        crate::log_info!("shard {index}: spawned (pid {})", child.id());
    }
    Ok(child)
}

/// Spawn, supervise, crash-restart, and merge a sharded run. See the module
/// docs; returns once the merged output in `cfg.run_dir` is complete.
pub fn launch(cfg: &LaunchConfig) -> Result<LaunchReport, String> {
    if cfg.shards == 0 {
        return Err("launch needs --shards >= 1".to_string());
    }
    if let Some(0) = cfg.exchange_epoch {
        return Err("--exchange-epoch must be >= 1".to_string());
    }
    std::fs::create_dir_all(&cfg.run_dir)
        .map_err(|e| format!("creating {}: {e}", cfg.run_dir.display()))?;
    let out_rd = RunDir::open(&cfg.run_dir)
        .map_err(|e| format!("opening {}: {e}", cfg.run_dir.display()))?;
    if out_rd.has_results() {
        return Err(format!(
            "{} already holds merged results; pick a fresh --run-dir",
            cfg.run_dir.display()
        ));
    }

    // Create the shard dirs up front so the streaming merge can safely
    // canonicalize them before the children get going.
    let shard_dirs: Vec<PathBuf> = (0..cfg.shards)
        .map(|i| {
            let d = shard_dir(&cfg.run_dir, i);
            std::fs::create_dir_all(&d).map(|_| d)
        })
        .collect::<Result<_, _>>()
        .map_err(|e| format!("creating shard dirs: {e}"))?;
    let mut watcher = MergeWatcher::new(&cfg.run_dir, &shard_dirs)?;

    let mut procs: Vec<ShardProc> = Vec::new();
    for index in 0..cfg.shards {
        procs.push(ShardProc {
            index,
            child: Some(spawn_shard(cfg, index, false)?),
            restarts: 0,
            done: false,
        });
    }

    let mut last_cells = usize::MAX;
    let supervise = |procs: &mut Vec<ShardProc>,
                     watcher: &mut MergeWatcher,
                     last_cells: &mut usize|
     -> Result<bool, String> {
        let mut all_done = true;
        for s in procs.iter_mut() {
            if s.done {
                continue;
            }
            all_done = false;
            let Some(child) = s.child.as_mut() else {
                continue;
            };
            match child.try_wait() {
                Ok(None) => {}
                Ok(Some(status)) if status.success() => {
                    s.child = None;
                    s.done = true;
                }
                Ok(Some(status)) => {
                    s.child = None;
                    if s.restarts >= cfg.max_restarts {
                        return Err(format!(
                            "shard {} failed with {status} after {} restart(s); see {}",
                            s.index,
                            s.restarts,
                            cfg.run_dir.join(format!("shard-{}.log", s.index)).display()
                        ));
                    }
                    s.restarts += 1;
                    crate::log_warn!(
                        "shard {} exited with {status}; restarting ({}/{})",
                        s.index,
                        s.restarts,
                        cfg.max_restarts
                    );
                    s.child = Some(spawn_shard(cfg, s.index, true)?);
                }
                Err(e) => return Err(format!("waiting on shard {}: {e}", s.index)),
            }
        }
        // Live streaming merge: fold whatever the shards appended since the
        // last cycle and narrate progress on change.
        let status = watcher.poll()?;
        if status.cells != *last_cells {
            *last_cells = status.cells;
            crate::log_info!("launch: {}", status.render());
        }
        Ok(all_done)
    };

    {
        let guard = ReapOnDrop(&mut procs);
        loop {
            match supervise(&mut *guard.0, &mut watcher, &mut last_cells) {
                Ok(true) => break,
                Ok(false) => std::thread::sleep(Duration::from_millis(cfg.poll_ms.max(1))),
                Err(e) => return Err(e), // guard kills the survivors
            }
        }
        // All children exited cleanly; nothing left for the guard to reap.
    }

    let merge = watcher.finalize()?;
    out_rd
        .mark_complete()
        .map_err(|e| format!("writing completion marker: {e}"))?;
    Ok(LaunchReport {
        shards: procs
            .iter()
            .map(|s| ShardOutcome {
                index: s.index,
                dir: shard_dir(&cfg.run_dir, s.index),
                log: cfg.run_dir.join(format!("shard-{}.log", s.index)),
                restarts: s.restarts,
            })
            .collect(),
        merge,
    })
}
