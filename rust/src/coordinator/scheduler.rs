//! Suite orchestration v2: the work-stealing cell scheduler behind
//! `run_suite`.
//!
//! One *cell* is a `(strategy, task, seed)` triple. The scheduler
//!   1. restores already-completed cells from the run directory's JSONL
//!     checkpoint (resume skips them entirely),
//!   2. dispatches the remaining cells over the work-stealing pool
//!     (`util::pool::run_streaming`),
//!   3. streams every finished cell to `results.jsonl` the moment it
//!     completes, and
//!   4. folds each finished cell's skill observations into the persistent
//!     long-term store and rewrites `skills.json` atomically after each
//!     task.
//!
//! Determinism contract: every cell runs against the same immutable
//! skill-store *snapshot* taken at run start (and persisted into the run
//! directory), so results are independent of worker count and completion
//! order — parallel == serial, and a resumed run reproduces an
//! uninterrupted one bit-for-bit. The *live* store only ever absorbs
//! additive merges, so its final state is order-independent too.

use std::path::PathBuf;
use std::sync::Arc;

use super::checkpoint::{CellKey, RunDir, RunManifest};
use super::loop_runner::{run_task, LoopConfig, TaskResult};
use crate::baselines::Strategy;
use crate::bench_suite::Task;
use crate::memory::long_term::kb_content;
use crate::memory::long_term::SkillStore;
use crate::util::pool;

/// Orchestration options for one suite run.
#[derive(Debug, Clone, Default)]
pub struct SuiteOptions {
    /// Directory for the JSONL checkpoint + memory snapshot. None = fully
    /// in-memory (the v1 behavior).
    pub run_dir: Option<PathBuf>,
    /// Restore completed cells from `run_dir` and run only the rest.
    pub resume: bool,
    /// Stop dispatching once this many cells are complete (restored +
    /// fresh). Simulates a killed run for tests and the CI smoke path; the
    /// returned results then cover only the completed prefix of the matrix.
    pub stop_after: Option<usize>,
}

impl SuiteOptions {
    pub fn in_dir<P: Into<PathBuf>>(path: P) -> SuiteOptions {
        SuiteOptions {
            run_dir: Some(path.into()),
            ..SuiteOptions::default()
        }
    }

    pub fn resumed<P: Into<PathBuf>>(path: P) -> SuiteOptions {
        SuiteOptions {
            run_dir: Some(path.into()),
            resume: true,
            ..SuiteOptions::default()
        }
    }
}

/// Run one strategy's cells, in deterministic (task-major, seed-minor)
/// result order. See module docs for the orchestration contract.
pub fn run_strategy(
    tasks: &[Task],
    strategy: &Strategy,
    cfg: &LoopConfig,
    seeds: &[u64],
    workers: usize,
    opts: &SuiteOptions,
) -> Result<Vec<TaskResult>, String> {
    // Cell matrix, task-major (matches the v1 fan-out order).
    let cells: Vec<(usize, u64)> = (0..tasks.len())
        .flat_map(|t| seeds.iter().map(move |s| (t, *s)))
        .collect();

    // ---- checkpoint directory ------------------------------------------
    let run_dir = match &opts.run_dir {
        Some(path) => Some(RunDir::open(path).map_err(|e| format!("opening run dir: {e}"))?),
        None => None,
    };
    let task_ids: Vec<&str> = tasks.iter().map(|t| t.id.as_str()).collect();
    let expected = RunManifest {
        n_tasks: tasks.len(),
        seeds: seeds.to_vec(),
        rt: cfg.rt,
        at: cfg.at,
        fingerprint: RunManifest::fingerprint_tasks(&task_ids),
    };
    let mut restored: std::collections::BTreeMap<usize, TaskResult> = Default::default();
    if let Some(rd) = &run_dir {
        match rd.read_manifest()? {
            Some(m) if m != expected => {
                return Err(format!(
                    "run dir {} was written for a different matrix \
                     (manifest {m:?} != expected {expected:?}); refusing to mix results",
                    rd.root().display()
                ));
            }
            Some(_) => {}
            None => rd
                .write_manifest(&expected)
                .map_err(|e| format!("writing manifest: {e}"))?,
        }

        let on_disk = rd.load().map_err(|e| format!("loading checkpoint: {e}"))?;
        let mut index = std::collections::BTreeMap::new();
        for (ci, &(ti, seed)) in cells.iter().enumerate() {
            index.insert((tasks[ti].id.as_str(), seed), ci);
        }
        let mut mine = 0usize;
        for (key, result) in on_disk {
            if key.strategy != strategy.name {
                continue;
            }
            mine += 1;
            match index.get(&(key.task_id.as_str(), key.seed)) {
                Some(&ci) => {
                    restored.insert(ci, result);
                }
                None => crate::log_warn!(
                    "checkpoint cell ({}, {}, {}) is not in this matrix; ignoring",
                    key.strategy,
                    key.task_id,
                    key.seed
                ),
            }
        }
        if !opts.resume && mine > 0 {
            return Err(format!(
                "run dir {} already holds {mine} result(s) for strategy {:?}; \
                 pass resume (--resume) or use a fresh directory",
                rd.root().display(),
                strategy.name
            ));
        }
    }

    // ---- persistent long-term memory -----------------------------------
    let live_path = cfg.memory_dir.as_ref().map(|d| d.join("skills.json"));
    let snapshot: Option<Arc<SkillStore>> = if let Some(s) = &cfg.skills {
        Some(s.clone())
    } else if let Some(rd) = run_dir
        .as_ref()
        .filter(|rd| opts.resume && rd.memory_snapshot_path(strategy.name).exists())
    {
        // Resume: warm-start from the snapshot this strategy's interrupted
        // run took, so the remaining cells see exactly what the finished
        // cells saw (snapshots are per-strategy: in a matrix run, later
        // strategies start from a live store that already includes earlier
        // strategies' merges).
        Some(Arc::new(SkillStore::load(&rd.memory_snapshot_path(strategy.name))?))
    } else if let Some(path) = &live_path {
        Some(Arc::new(SkillStore::load(path)?))
    } else {
        None
    };
    if let (Some(rd), Some(snap)) = (&run_dir, &snapshot) {
        let snap_path = rd.memory_snapshot_path(strategy.name);
        if !snap_path.exists() {
            snap.save(&snap_path)
                .map_err(|e| format!("writing memory snapshot: {e}"))?;
        }
    }
    // The live store absorbs observations as cells finish. It starts from
    // the current on-disk state (on resume that already includes the
    // interrupted run's merges; restored cells are NOT re-merged).
    let mut live_store: Option<SkillStore> = match &live_path {
        Some(path) => Some(SkillStore::load(path)?),
        None => None,
    };
    if let Some(dir) = &cfg.memory_dir {
        // Make the memory directory self-describing: curated KB next to the
        // learned store.
        let kb_path = dir.join("kb.json");
        if !kb_path.exists() {
            std::fs::create_dir_all(dir).map_err(|e| format!("creating memory dir: {e}"))?;
            std::fs::write(&kb_path, format!("{}\n", kb_content::export_kb()))
                .map_err(|e| format!("writing kb export: {e}"))?;
        }
    }

    let mut cfg_run = cfg.clone();
    cfg_run.skills = snapshot;

    // ---- dispatch -------------------------------------------------------
    let mut pending: Vec<usize> = (0..cells.len()).filter(|ci| !restored.contains_key(ci)).collect();
    if let Some(stop) = opts.stop_after {
        pending.truncate(stop.saturating_sub(restored.len()));
    }

    let mut sink_err: Option<String> = None;
    let fresh = pool::run_streaming(
        &pending,
        workers,
        |_, &ci| {
            let (ti, seed) = cells[ci];
            let mut c = cfg_run.clone();
            c.run_seed = seed;
            run_task(&tasks[ti], strategy, &c)
        },
        |ip, r| {
            let (ti, seed) = cells[pending[ip]];
            if let Some(rd) = &run_dir {
                let key = CellKey {
                    strategy: strategy.name.to_string(),
                    task_id: tasks[ti].id.clone(),
                    seed,
                };
                if let Err(e) = rd.append(&key, r) {
                    sink_err.get_or_insert(format!("appending checkpoint: {e}"));
                }
            }
            if let (Some(store), Some(path)) = (live_store.as_mut(), live_path.as_ref()) {
                store.merge(&r.skill_obs);
                if let Err(e) = store.save(path) {
                    sink_err.get_or_insert(format!("saving skill store: {e}"));
                }
            }
        },
    );
    if let Some(e) = sink_err {
        return Err(e);
    }

    // ---- assemble in matrix order ---------------------------------------
    let mut out = Vec::with_capacity(restored.len() + fresh.len());
    let mut fresh_iter = fresh.into_iter();
    let mut next_pending = 0usize;
    for ci in 0..cells.len() {
        if let Some(r) = restored.remove(&ci) {
            out.push(r);
        } else if next_pending < pending.len() && pending[next_pending] == ci {
            out.push(fresh_iter.next().expect("one fresh result per pending cell"));
            next_pending += 1;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::bench_suite;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ks-sched-{tag}-{}", std::process::id()))
    }

    fn slice(n: usize) -> Vec<Task> {
        bench_suite::level_suite(42, 1).into_iter().take(n).collect()
    }

    #[test]
    fn stop_after_completes_a_prefix_and_resume_finishes_it() {
        let dir = tmp_dir("resume");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(4);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();

        let full = run_strategy(&tasks, &strat, &cfg, &[0, 1], 4, &SuiteOptions::default()).unwrap();
        assert_eq!(full.len(), 8);

        let mut opts = SuiteOptions::in_dir(&dir);
        opts.stop_after = Some(3);
        let partial = run_strategy(&tasks, &strat, &cfg, &[0, 1], 4, &opts).unwrap();
        assert_eq!(partial.len(), 3);

        // Fresh (non-resume) reuse of a dirty dir is refused.
        let err = run_strategy(&tasks, &strat, &cfg, &[0, 1], 4, &SuiteOptions::in_dir(&dir));
        assert!(err.is_err());

        let resumed =
            run_strategy(&tasks, &strat, &cfg, &[0, 1], 4, &SuiteOptions::resumed(&dir)).unwrap();
        assert_eq!(resumed.len(), 8);
        for (a, b) in full.iter().zip(&resumed) {
            assert_eq!(a.task_id, b.task_id);
            assert_eq!(a.best_speedup, b.best_speedup, "{}", a.task_id);
            assert_eq!(a.rounds.len(), b.rounds.len());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatched_matrix_is_refused() {
        let dir = tmp_dir("mismatch");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(3);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        run_strategy(&tasks, &strat, &cfg, &[0], 2, &SuiteOptions::in_dir(&dir)).unwrap();
        let other = slice(2);
        let err = run_strategy(&other, &strat, &cfg, &[0], 2, &SuiteOptions::resumed(&dir));
        assert!(err.is_err(), "different matrix must be refused");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn memory_dir_persists_skills_and_kb() {
        let dir = tmp_dir("memdir");
        let mem = dir.join("memory");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(3);
        let strat = baselines::kernelskill();
        let mut cfg = LoopConfig::default();
        cfg.memory_dir = Some(mem.clone());
        run_strategy(&tasks, &strat, &cfg, &[0], 2, &SuiteOptions::default()).unwrap();
        let store = SkillStore::load(&mem.join("skills.json")).unwrap();
        assert!(store.observations > 0, "L1 slice should produce observations");
        assert!(mem.join("kb.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
