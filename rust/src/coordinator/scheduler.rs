//! Suite orchestration v2: the work-stealing cell scheduler behind
//! `run_suite`.
//!
//! One *cell* is a `(strategy, task, seed)` triple. The scheduler
//!   1. restores already-completed cells from the run directory's JSONL
//!     checkpoint (resume skips them entirely),
//!   2. dispatches the remaining cells over the work-stealing pool
//!     (`util::pool::run_streaming`),
//!   3. streams every finished cell to `results.jsonl` the moment it
//!     completes, and
//!   4. folds each finished cell's skill observations into the persistent
//!     long-term store and rewrites `skills.json` atomically after each
//!     task.
//!
//! Determinism contract: every cell runs against the same immutable
//! skill-store *snapshot* taken at run start (and persisted into the run
//! directory), so results are independent of worker count and completion
//! order — parallel == serial, and a resumed run reproduces an
//! uninterrupted one bit-for-bit. The *live* store only ever absorbs
//! additive merges (exact-sum gain totals; generation stamps via `max`),
//! so its final state is order-independent too — at the bit level. Skill
//! observations are stamped with a fold epoch fixed at run start (the
//! warm-start snapshot's generation + 1; run-dir stores always fold at
//! epoch 1 over a cold base), never with completion order or wall clock —
//! the v3 aging clock that keeps resume and merge byte-deterministic.
//!
//! Sharding: with [`SuiteOptions::shard`] set, the scheduler claims only a
//! deterministic round-robin slice of the cell matrix ([`Shard::owns`]) and
//! streams it to this process's own run dir; `coordinator::merge` unions
//! the per-shard dirs back into one that is indistinguishable from a
//! single-process run.

use std::path::PathBuf;
use std::sync::Arc;

use super::checkpoint::{CellKey, RunDir, RunManifest};
use super::loop_runner::{run_task, LoopConfig, TaskResult};
use crate::baselines::Strategy;
use crate::bench_suite::Task;
use crate::memory::long_term::kb_content;
use crate::memory::long_term::SkillStore;
use crate::util::pool;

/// One process's deterministic slice of the cell matrix.
///
/// Cells are claimed round-robin over the flat task-major cell index:
/// shard `i` of `N` owns exactly the cells whose index is `i (mod N)`.
/// The claim is a pure function of (index, count, cell position), so the
/// shard slices are a disjoint exact cover of the matrix, stable under
/// re-enumeration, and balanced to within one cell — no coordination
/// between processes is ever needed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    /// This process's shard index, in `0..count`.
    pub index: usize,
    /// Total number of shards the matrix is split across.
    pub count: usize,
}

impl Shard {
    /// Reject impossible assignments (zero shards, index out of range).
    pub fn validate(&self) -> Result<(), String> {
        if self.count == 0 {
            return Err("--shards must be >= 1".to_string());
        }
        if self.index >= self.count {
            return Err(format!(
                "--shard-index {} out of range for --shards {}",
                self.index, self.count
            ));
        }
        Ok(())
    }

    /// Does this shard own the cell at flat (task-major) index `cell_index`?
    pub fn owns(&self, cell_index: usize) -> bool {
        cell_index % self.count == self.index
    }
}

/// Orchestration options for one suite run.
#[derive(Debug, Clone, Default)]
pub struct SuiteOptions {
    /// Directory for the JSONL checkpoint + memory snapshot. None = fully
    /// in-memory (the v1 behavior).
    pub run_dir: Option<PathBuf>,
    /// Restore completed cells from `run_dir` and run only the rest.
    pub resume: bool,
    /// Stop dispatching once this many cells are complete (restored +
    /// fresh). Simulates a killed run for tests and the CI smoke path; the
    /// returned results then cover only the completed prefix of the matrix.
    pub stop_after: Option<usize>,
    /// Run only this shard's slice of the cell matrix (None = all cells).
    /// Each shard must stream to its own run dir; `merge` unions them.
    pub shard: Option<Shard>,
}

impl SuiteOptions {
    /// Fresh checkpointed run streaming into `path`.
    pub fn in_dir<P: Into<PathBuf>>(path: P) -> SuiteOptions {
        SuiteOptions {
            run_dir: Some(path.into()),
            ..SuiteOptions::default()
        }
    }

    /// Resume a checkpointed run from `path`.
    pub fn resumed<P: Into<PathBuf>>(path: P) -> SuiteOptions {
        SuiteOptions {
            run_dir: Some(path.into()),
            resume: true,
            ..SuiteOptions::default()
        }
    }

    /// Restrict the run to shard `index` of `count`.
    pub fn with_shard(mut self, index: usize, count: usize) -> SuiteOptions {
        self.shard = Some(Shard { index, count });
        self
    }
}

/// Run one strategy's cells, in deterministic (task-major, seed-minor)
/// result order. See module docs for the orchestration contract.
pub fn run_strategy(
    tasks: &[Task],
    strategy: &Strategy,
    cfg: &LoopConfig,
    seeds: &[u64],
    workers: usize,
    opts: &SuiteOptions,
) -> Result<Vec<TaskResult>, String> {
    // Cell matrix, task-major (matches the v1 fan-out order).
    let cells: Vec<(usize, u64)> = (0..tasks.len())
        .flat_map(|t| seeds.iter().map(move |s| (t, *s)))
        .collect();
    if let Some(s) = &opts.shard {
        s.validate()?;
    }
    let owns = |ci: usize| opts.shard.map_or(true, |s| s.owns(ci));

    // ---- checkpoint directory ------------------------------------------
    let run_dir = match &opts.run_dir {
        Some(path) => Some(RunDir::open(path).map_err(|e| format!("opening run dir: {e}"))?),
        None => None,
    };
    // Both the run dir and the memory dir own a `skills.json` (checkpoint
    // fold vs. live long-term store); sharing one directory would have them
    // silently clobber each other, so refuse before writing anything.
    if let (Some(rd), Some(mem)) = (&run_dir, &cfg.memory_dir) {
        let same = match (std::fs::canonicalize(rd.root()), std::fs::canonicalize(mem)) {
            (Ok(a), Ok(b)) => a == b,
            _ => rd.root() == mem.as_path(),
        };
        if same {
            return Err(format!(
                "--run-dir and --memory-dir must be different directories \
                 ({}): both write a skills.json there",
                rd.root().display()
            ));
        }
    }
    let task_ids: Vec<&str> = tasks.iter().map(|t| t.id.as_str()).collect();
    let expected = RunManifest {
        n_tasks: tasks.len(),
        seeds: seeds.to_vec(),
        rt: cfg.rt,
        at: cfg.at,
        fingerprint: RunManifest::fingerprint_tasks(&task_ids),
        shards: opts.shard.map_or(1, |s| s.count),
        shard_index: opts.shard.map_or(0, |s| s.index),
    };
    let mut restored: std::collections::BTreeMap<usize, TaskResult> = Default::default();
    // Fold of every checkpointed cell's observations (all strategies), so
    // `merge` can combine shards' stores without re-running anything.
    // Rebuilt from the checkpoint on open (never loaded) and saved once
    // after dispatch: a killed run's on-disk copy may lag results.jsonl,
    // but reopening — or `merge`, which derives the authoritative store
    // from the cells — always reconciles it.
    let mut run_store: Option<SkillStore> = None;
    if let Some(rd) = &run_dir {
        match rd.read_manifest()? {
            Some(m) if m != expected => {
                return Err(format!(
                    "run dir {} was written for a different matrix or shard \
                     (manifest {m:?} != expected {expected:?}); refusing to mix results",
                    rd.root().display()
                ));
            }
            Some(_) => {}
            None => rd
                .write_manifest(&expected)
                .map_err(|e| format!("writing manifest: {e}"))?,
        }

        let on_disk = rd.load().map_err(|e| format!("loading checkpoint: {e}"))?;
        let mut rs = SkillStore::new();
        for result in on_disk.values() {
            rs.merge(&result.skill_obs);
        }
        rs.save(&rd.skills_path())
            .map_err(|e| format!("writing run-dir skill store: {e}"))?;
        run_store = Some(rs);

        let mut index = std::collections::BTreeMap::new();
        for (ci, &(ti, seed)) in cells.iter().enumerate() {
            index.insert((tasks[ti].id.as_str(), seed), ci);
        }
        let mut mine = 0usize;
        for (key, result) in on_disk {
            if key.strategy != strategy.name {
                continue;
            }
            mine += 1;
            match index.get(&(key.task_id.as_str(), key.seed)) {
                Some(&ci) if owns(ci) => {
                    restored.insert(ci, result);
                }
                Some(_) => crate::log_warn!(
                    "checkpoint cell ({}, {}, {}) belongs to another shard; ignoring",
                    key.strategy,
                    key.task_id,
                    key.seed
                ),
                None => crate::log_warn!(
                    "checkpoint cell ({}, {}, {}) is not in this matrix; ignoring",
                    key.strategy,
                    key.task_id,
                    key.seed
                ),
            }
        }
        if !opts.resume && mine > 0 {
            return Err(format!(
                "run dir {} already holds {mine} result(s) for strategy {:?}; \
                 pass resume (--resume) or use a fresh directory",
                rd.root().display(),
                strategy.name
            ));
        }
    }

    // ---- persistent long-term memory -----------------------------------
    let live_path = cfg.memory_dir.as_ref().map(|d| d.join("skills.json"));
    let snapshot: Option<Arc<SkillStore>> = if let Some(s) = &cfg.skills {
        Some(s.clone())
    } else if let Some(rd) = run_dir
        .as_ref()
        .filter(|rd| opts.resume && rd.memory_snapshot_path(strategy.name).exists())
    {
        // Resume: warm-start from the snapshot this strategy's interrupted
        // run took, so the remaining cells see exactly what the finished
        // cells saw (snapshots are per-strategy: in a matrix run, later
        // strategies start from a live store that already includes earlier
        // strategies' merges).
        Some(Arc::new(SkillStore::load(&rd.memory_snapshot_path(strategy.name))?))
    } else if let Some(path) = &live_path {
        Some(Arc::new(SkillStore::load(path)?))
    } else {
        None
    };
    if let (Some(rd), Some(snap)) = (&run_dir, &snapshot) {
        let snap_path = rd.memory_snapshot_path(strategy.name);
        if !snap_path.exists() {
            snap.save(&snap_path)
                .map_err(|e| format!("writing memory snapshot: {e}"))?;
        }
    }
    // The live store absorbs observations as cells finish. It starts from
    // the current on-disk state (on resume that already includes the
    // interrupted run's merges; restored cells are NOT re-merged).
    //
    // Fold epoch: this run's observations are stamped with generation
    // snapshot+1, derived from the warm-start snapshot rather than the
    // live store itself so a resumed run reuses the interrupted run's
    // epoch (the on-disk store already carries the bump) — fold order and
    // kill points can never change a stamp. Advancing the clock per
    // strategy-suite run is what ages stats that stop being re-observed.
    let mut live_store: Option<SkillStore> = match &live_path {
        Some(path) => Some(SkillStore::load(path)?),
        None => None,
    };
    if let Some(store) = live_store.as_mut() {
        let base_gen = snapshot
            .as_deref()
            .map(|s| s.generation)
            .unwrap_or(store.generation);
        store.generation = store.generation.max(base_gen + 1);
    }
    if let Some(dir) = &cfg.memory_dir {
        // Make the memory directory self-describing: curated KB next to the
        // learned store.
        let kb_path = dir.join("kb.json");
        if !kb_path.exists() {
            std::fs::create_dir_all(dir).map_err(|e| format!("creating memory dir: {e}"))?;
            std::fs::write(&kb_path, format!("{}\n", kb_content::export_kb()))
                .map_err(|e| format!("writing kb export: {e}"))?;
        }
    }

    let mut cfg_run = cfg.clone();
    cfg_run.skills = snapshot;

    // ---- dispatch -------------------------------------------------------
    // Only this shard's slice of the matrix (every cell when unsharded).
    let mut pending: Vec<usize> = (0..cells.len())
        .filter(|&ci| owns(ci) && !restored.contains_key(&ci))
        .collect();
    if let Some(stop) = opts.stop_after {
        pending.truncate(stop.saturating_sub(restored.len()));
    }

    let mut sink_err: Option<String> = None;
    let fresh = pool::run_streaming(
        &pending,
        workers,
        |_, &ci| {
            let (ti, seed) = cells[ci];
            let mut c = cfg_run.clone();
            c.run_seed = seed;
            run_task(&tasks[ti], strategy, &c)
        },
        |ip, r| {
            let (ti, seed) = cells[pending[ip]];
            if let Some(rd) = &run_dir {
                let key = CellKey {
                    strategy: strategy.name.to_string(),
                    task_id: tasks[ti].id.clone(),
                    seed,
                };
                if let Err(e) = rd.append(&key, r) {
                    sink_err.get_or_insert(format!("appending checkpoint: {e}"));
                }
            }
            if let (Some(store), Some(path)) = (live_store.as_mut(), live_path.as_ref()) {
                store.merge(&r.skill_obs);
                if let Err(e) = store.save(path) {
                    sink_err.get_or_insert(format!("saving skill store: {e}"));
                }
            }
            if let Some(rs) = run_store.as_mut() {
                // Folded per cell, saved once after the dispatch loop: the
                // on-disk copy is only advisory (it is rebuilt from the
                // checkpoint on open, and `merge` derives the authoritative
                // store from the cells), so per-cell rewrites would be
                // wasted I/O.
                rs.merge(&r.skill_obs);
            }
        },
    );
    if let Some(e) = sink_err {
        return Err(e);
    }
    if let (Some(rs), Some(rd)) = (&run_store, &run_dir) {
        rs.save(&rd.skills_path())
            .map_err(|e| format!("saving run-dir skill store: {e}"))?;
    }

    // ---- assemble in matrix order ---------------------------------------
    let mut out = Vec::with_capacity(restored.len() + fresh.len());
    let mut fresh_iter = fresh.into_iter();
    let mut next_pending = 0usize;
    for ci in 0..cells.len() {
        if let Some(r) = restored.remove(&ci) {
            out.push(r);
        } else if next_pending < pending.len() && pending[next_pending] == ci {
            out.push(fresh_iter.next().expect("one fresh result per pending cell"));
            next_pending += 1;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::bench_suite;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ks-sched-{tag}-{}", std::process::id()))
    }

    fn slice(n: usize) -> Vec<Task> {
        bench_suite::level_suite(42, 1).into_iter().take(n).collect()
    }

    #[test]
    fn stop_after_completes_a_prefix_and_resume_finishes_it() {
        let dir = tmp_dir("resume");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(4);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();

        let full = run_strategy(&tasks, &strat, &cfg, &[0, 1], 4, &SuiteOptions::default()).unwrap();
        assert_eq!(full.len(), 8);

        let mut opts = SuiteOptions::in_dir(&dir);
        opts.stop_after = Some(3);
        let partial = run_strategy(&tasks, &strat, &cfg, &[0, 1], 4, &opts).unwrap();
        assert_eq!(partial.len(), 3);

        // Fresh (non-resume) reuse of a dirty dir is refused.
        let err = run_strategy(&tasks, &strat, &cfg, &[0, 1], 4, &SuiteOptions::in_dir(&dir));
        assert!(err.is_err());

        let resumed =
            run_strategy(&tasks, &strat, &cfg, &[0, 1], 4, &SuiteOptions::resumed(&dir)).unwrap();
        assert_eq!(resumed.len(), 8);
        for (a, b) in full.iter().zip(&resumed) {
            assert_eq!(a.task_id, b.task_id);
            assert_eq!(a.best_speedup, b.best_speedup, "{}", a.task_id);
            assert_eq!(a.rounds.len(), b.rounds.len());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatched_matrix_is_refused() {
        let dir = tmp_dir("mismatch");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(3);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        run_strategy(&tasks, &strat, &cfg, &[0], 2, &SuiteOptions::in_dir(&dir)).unwrap();
        let other = slice(2);
        let err = run_strategy(&other, &strat, &cfg, &[0], 2, &SuiteOptions::resumed(&dir));
        assert!(err.is_err(), "different matrix must be refused");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_runs_only_its_slice_and_slices_union_to_the_full_run() {
        let tasks = slice(3);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        let seeds = [0u64, 1];
        let full = run_strategy(&tasks, &strat, &cfg, &seeds, 4, &SuiteOptions::default()).unwrap();
        assert_eq!(full.len(), 6);

        for count in [2usize, 3] {
            let mut seen = 0usize;
            for index in 0..count {
                let opts = SuiteOptions::default().with_shard(index, count);
                let part = run_strategy(&tasks, &strat, &cfg, &seeds, 4, &opts).unwrap();
                let owned: Vec<usize> = (0..6).filter(|&ci| Shard { index, count }.owns(ci)).collect();
                assert_eq!(part.len(), owned.len(), "shard {index}/{count}");
                for (r, &ci) in part.iter().zip(&owned) {
                    assert_eq!(r.task_id, full[ci].task_id, "shard {index}/{count}");
                    assert_eq!(r.best_speedup, full[ci].best_speedup, "shard {index}/{count}");
                    assert_eq!(r.rounds, full[ci].rounds, "shard {index}/{count}");
                }
                seen += part.len();
            }
            assert_eq!(seen, 6, "{count} shards must exactly cover the matrix");
        }
    }

    #[test]
    fn invalid_shard_is_refused() {
        let tasks = slice(1);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        for (index, count) in [(0usize, 0usize), (2, 2), (5, 3)] {
            let opts = SuiteOptions::default().with_shard(index, count);
            assert!(
                run_strategy(&tasks, &strat, &cfg, &[0], 1, &opts).is_err(),
                "shard {index}/{count} must be rejected"
            );
        }
    }

    #[test]
    fn resume_with_different_shard_settings_is_refused() {
        let dir = tmp_dir("shard-mix");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(2);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        let opts = SuiteOptions::in_dir(&dir).with_shard(0, 2);
        run_strategy(&tasks, &strat, &cfg, &[0], 2, &opts).unwrap();
        // Same dir, different shard assignment (or unsharded): refused.
        let other = SuiteOptions::resumed(&dir).with_shard(1, 2);
        assert!(run_strategy(&tasks, &strat, &cfg, &[0], 2, &other).is_err());
        let unsharded = SuiteOptions::resumed(&dir);
        assert!(run_strategy(&tasks, &strat, &cfg, &[0], 2, &unsharded).is_err());
        // The matching shard resumes cleanly.
        let same = SuiteOptions::resumed(&dir).with_shard(0, 2);
        assert!(run_strategy(&tasks, &strat, &cfg, &[0], 2, &same).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_dir_equal_to_memory_dir_is_refused() {
        // Both dirs own a skills.json (checkpoint fold vs. live long-term
        // store); sharing one path would silently clobber the memory.
        let dir = tmp_dir("collide");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(1);
        let strat = baselines::kernelskill();
        let mut cfg = LoopConfig::default();
        cfg.memory_dir = Some(dir.clone());
        let err = run_strategy(&tasks, &strat, &cfg, &[0], 1, &SuiteOptions::in_dir(&dir));
        assert!(err.is_err(), "run_dir == memory_dir must be rejected");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_dir_skill_store_tracks_checkpointed_observations() {
        let dir = tmp_dir("rundir-skills");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(2);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        let results =
            run_strategy(&tasks, &strat, &cfg, &[0], 2, &SuiteOptions::in_dir(&dir)).unwrap();
        let store = SkillStore::load(&dir.join("skills.json")).unwrap();
        let expected: u64 = results.iter().map(|r| r.skill_obs.len() as u64).sum();
        assert_eq!(store.observations, expected);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn memory_dir_persists_skills_and_kb() {
        let dir = tmp_dir("memdir");
        let mem = dir.join("memory");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(3);
        let strat = baselines::kernelskill();
        let mut cfg = LoopConfig::default();
        cfg.memory_dir = Some(mem.clone());
        run_strategy(&tasks, &strat, &cfg, &[0], 2, &SuiteOptions::default()).unwrap();
        let store = SkillStore::load(&mem.join("skills.json")).unwrap();
        assert!(store.observations > 0, "L1 slice should produce observations");
        assert!(mem.join("kb.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
