//! Suite orchestration v2: the work-stealing cell scheduler behind
//! `run_suite`.
//!
//! One *cell* is a `(strategy, task, seed)` triple. The scheduler
//!   1. restores already-completed cells from the run directory's JSONL
//!     checkpoint (resume skips them entirely),
//!   2. dispatches the remaining cells over the work-stealing pool
//!     (`util::pool::run_streaming`),
//!   3. streams every finished cell to `results.jsonl` the moment it
//!     completes, and
//!   4. folds each finished cell's skill observations into the persistent
//!     long-term store in memory, rewriting the store atomically at
//!     window (fold-epoch) boundaries — serde stays out of the per-cell
//!     path, and because store merges are additive and exact the final
//!     bytes match per-cell saving. The live store uses the v4 segmented
//!     layout ([`SegmentedSkillStore`]): advancing the fold epoch rotates
//!     the previous head into an immutable segment file, so the
//!     boundary rewrite touches only the small manifest + head, never the
//!     accumulated history.
//!
//! Determinism contract: every cell runs against an immutable skill-store
//! *snapshot* — the run-start snapshot (persisted into the run directory),
//! advanced only at deterministic exchange-epoch boundaries when live
//! memory exchange is on — so results are independent of worker count and
//! completion order — parallel == serial, and a resumed run reproduces an
//! uninterrupted one bit-for-bit. The *live* store only ever absorbs
//! additive merges (exact-sum gain totals; generation stamps via `max`),
//! so its final state is order-independent too — at the bit level. Skill
//! observations are stamped with a fold epoch fixed at run start (the
//! warm-start snapshot's generation + 1; run-dir stores always fold at
//! epoch 1 over a cold base), never with completion order or wall clock —
//! the v4 aging clock that keeps resume and merge byte-deterministic.
//!
//! Sharding: with [`SuiteOptions::shard`] set, the scheduler claims only a
//! deterministic round-robin slice of the cell matrix ([`Shard::owns`]) and
//! streams it to this process's own run dir; `coordinator::merge` unions
//! the per-shard dirs back into one that is indistinguishable from a
//! single-process run.
//!
//! Live memory exchange: with [`SuiteOptions::exchange`] set, the matrix is
//! additionally cut into fixed-length *epochs* over the global cell index.
//! At the end of each epoch every shard publishes the skill-store delta of
//! its own cells in that window (`<exchange-dir>/<strategy>/epoch-K.shard-I
//! .json`, written atomically), and before running epoch K+1 it folds every
//! shard's epoch-K delta into its retrieval snapshot — so late cells
//! benefit from skills learned anywhere in the fleet. Determinism is
//! preserved because the epoch cut is a pure function of the matrix, delta
//! stores fold commutatively at the bit level, and shards *wait* for their
//! peers at each boundary: the snapshot any cell sees depends only on
//! (matrix, base memory, epoch length) — never on shard count, worker
//! count, completion order, or crash/resume history. The protocol is
//! specified in `docs/memory-formats.md`.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::checkpoint::{strategy_slug, CellKey, RunDir, RunManifest};
use super::loop_runner::{run_task, LoopConfig, TaskResult};
use crate::baselines::Strategy;
use crate::bench_suite::Task;
use crate::memory::long_term::kb_content;
use crate::memory::long_term::{SegmentedSkillStore, SkillStore};
use crate::util::pool;

/// One process's deterministic slice of the cell matrix.
///
/// Cells are claimed round-robin over the flat task-major cell index:
/// shard `i` of `N` owns exactly the cells whose index is `i (mod N)`.
/// The claim is a pure function of (index, count, cell position), so the
/// shard slices are a disjoint exact cover of the matrix, stable under
/// re-enumeration, and balanced to within one cell — no coordination
/// between processes is ever needed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    /// This process's shard index, in `0..count`.
    pub index: usize,
    /// Total number of shards the matrix is split across.
    pub count: usize,
}

impl Shard {
    /// Reject impossible assignments (zero shards, index out of range).
    pub fn validate(&self) -> Result<(), String> {
        if self.count == 0 {
            return Err("--shards must be >= 1".to_string());
        }
        if self.index >= self.count {
            return Err(format!(
                "--shard-index {} out of range for --shards {}",
                self.index, self.count
            ));
        }
        Ok(())
    }

    /// Does this shard own the cell at flat (task-major) index `cell_index`?
    pub fn owns(&self, cell_index: usize) -> bool {
        cell_index % self.count == self.index
    }
}

/// One process's contiguous batch of the cell matrix under elastic
/// lease scheduling.
///
/// Batch `k` of `B` over an `n`-cell matrix owns exactly the flat
/// (task-major) cell indices in `[k*n/B, (k+1)*n/B)` — a balanced exact
/// disjoint cover (batch sizes differ by at most one cell), computed
/// purely from `(k, B, n)` so placement needs no coordination. Batches
/// are contiguous rather than round-robin like [`Shard`] so each one
/// spans the fewest exchange windows possible: the peer-wait set at an
/// epoch boundary is only the batches *overlapping* that window, and a
/// batch nobody has claimed yet can never deadlock a window it owns no
/// cells in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Batch {
    /// This process's batch index, in `0..count`.
    pub index: usize,
    /// Total number of batches the matrix is cut into.
    pub count: usize,
}

impl Batch {
    /// Reject impossible assignments (zero batches, index out of range).
    pub fn validate(&self) -> Result<(), String> {
        if self.count == 0 {
            return Err("--batch-count must be >= 1".to_string());
        }
        if self.index >= self.count {
            return Err(format!(
                "--batch-index {} out of range for --batch-count {}",
                self.index, self.count
            ));
        }
        Ok(())
    }

    /// Half-open bounds `[lo, hi)` of this batch over an `n_cells` matrix.
    pub fn bounds(&self, n_cells: usize) -> (usize, usize) {
        batch_bounds(self.index, self.count, n_cells)
    }

    /// Does this batch own the cell at flat (task-major) index
    /// `cell_index` of an `n_cells` matrix?
    pub fn owns(&self, cell_index: usize, n_cells: usize) -> bool {
        let (lo, hi) = self.bounds(n_cells);
        (lo..hi).contains(&cell_index)
    }
}

/// Half-open bounds `[lo, hi)` of batch `index` of `count` over an
/// `n_cells` matrix: `[index*n/count, (index+1)*n/count)`.
pub fn batch_bounds(index: usize, count: usize, n_cells: usize) -> (usize, usize) {
    (index * n_cells / count, (index + 1) * n_cells / count)
}

/// Default epoch length (cells) when live memory exchange is enabled
/// without an explicit `--exchange-epoch`.
pub const DEFAULT_EXCHANGE_EPOCH: usize = 8;

/// Live memory-exchange configuration: shards publish per-epoch skill-store
/// deltas into a shared directory and fold every peer's deltas at epoch
/// boundaries. The on-disk protocol is specified in
/// `docs/memory-formats.md`.
#[derive(Debug, Clone)]
pub struct ExchangeOptions {
    /// Shared exchange directory (one per distributed run; per-strategy
    /// subdirectories are derived internally). Every shard of the run must
    /// point at the same directory.
    pub dir: PathBuf,
    /// Cells per epoch, over the global task-major cell index. Must match
    /// across shards (recorded in the manifest, so resume and merge refuse
    /// a mismatch).
    pub epoch_cells: usize,
    /// How long to wait for a peer's delta at an epoch boundary before
    /// failing (milliseconds). Must cover a launcher crash-restart cycle.
    pub wait_timeout_ms: u64,
    /// Poll interval while waiting for peer deltas (milliseconds).
    pub poll_ms: u64,
    /// Adaptive epoch schedule: window lengths double each epoch
    /// (`epoch_cells`, `2*epoch_cells`, `4*epoch_cells`, …) instead of
    /// staying fixed — eager exchange while the store is cold, amortized
    /// barriers once it is warm. Part of the experiment identity (recorded
    /// in the run manifest); see [`exchange_windows`].
    pub adaptive: bool,
}

impl ExchangeOptions {
    /// Exchange through `dir` with fixed `epoch_cells`-cell epochs and
    /// default wait/poll timings.
    pub fn new<P: Into<PathBuf>>(dir: P, epoch_cells: usize) -> ExchangeOptions {
        ExchangeOptions {
            dir: dir.into(),
            epoch_cells,
            wait_timeout_ms: 600_000,
            poll_ms: 20,
            adaptive: false,
        }
    }
}

/// The exchange-window cut of an `n_cells` matrix: half-open `[lo, hi)`
/// windows over the flat task-major cell index, in epoch order. Fixed mode
/// cuts equal `epoch_cells`-cell windows (the last may be short); adaptive
/// mode doubles the window length each epoch. Both cuts are pure functions
/// of `(n_cells, epoch_cells, adaptive)` — exactly the knobs the run
/// manifest records — so every slice of a fleet derives the same schedule
/// with no coordination, and the snapshot any cell retrieves against stays
/// a pure function of the matrix.
pub fn exchange_windows(n_cells: usize, epoch_cells: usize, adaptive: bool) -> Vec<(usize, usize)> {
    let mut windows = Vec::new();
    let mut lo = 0usize;
    let mut len = epoch_cells.max(1);
    while lo < n_cells {
        let hi = lo.saturating_add(len).min(n_cells);
        windows.push((lo, hi));
        lo = hi;
        if adaptive {
            len = len.saturating_mul(2);
        }
    }
    windows
}

/// Orchestration options for one suite run.
#[derive(Debug, Clone, Default)]
pub struct SuiteOptions {
    /// Directory for the JSONL checkpoint + memory snapshot. None = fully
    /// in-memory (the v1 behavior).
    pub run_dir: Option<PathBuf>,
    /// Restore completed cells from `run_dir` and run only the rest.
    pub resume: bool,
    /// Stop dispatching once this many cells are complete (restored +
    /// fresh). Simulates a killed run for tests and the CI smoke path; the
    /// returned results then cover only the completed prefix of the matrix.
    pub stop_after: Option<usize>,
    /// Run only this shard's slice of the cell matrix (None = all cells).
    /// Each shard must stream to its own run dir; `merge` unions them.
    pub shard: Option<Shard>,
    /// Run only this contiguous batch of the cell matrix (elastic lease
    /// scheduling; None = all cells). Mutually exclusive with `shard`.
    /// Each batch must stream to its own run dir; `merge` unions them.
    pub batch: Option<Batch>,
    /// Epoch-based live memory exchange between slices (None = off, the
    /// pre-exchange behavior).
    pub exchange: Option<ExchangeOptions>,
}

impl SuiteOptions {
    /// Fresh checkpointed run streaming into `path`.
    pub fn in_dir<P: Into<PathBuf>>(path: P) -> SuiteOptions {
        SuiteOptions {
            run_dir: Some(path.into()),
            ..SuiteOptions::default()
        }
    }

    /// Resume a checkpointed run from `path`.
    pub fn resumed<P: Into<PathBuf>>(path: P) -> SuiteOptions {
        SuiteOptions {
            run_dir: Some(path.into()),
            resume: true,
            ..SuiteOptions::default()
        }
    }

    /// Restrict the run to shard `index` of `count`.
    pub fn with_shard(mut self, index: usize, count: usize) -> SuiteOptions {
        self.shard = Some(Shard { index, count });
        self
    }

    /// Restrict the run to contiguous batch `index` of `count` (elastic
    /// lease scheduling).
    pub fn with_batch(mut self, index: usize, count: usize) -> SuiteOptions {
        self.batch = Some(Batch { index, count });
        self
    }

    /// Enable epoch-based live memory exchange through `dir`.
    pub fn with_exchange<P: Into<PathBuf>>(mut self, dir: P, epoch_cells: usize) -> SuiteOptions {
        self.exchange = Some(ExchangeOptions::new(dir, epoch_cells));
        self
    }
}

/// File name of one shard's delta for one epoch inside a per-strategy
/// exchange directory. The cross-machine transport layer parses these names
/// back with [`parse_exchange_delta_name`] to route deltas to the workers
/// that do not own them.
pub fn exchange_delta_name(epoch: usize, shard_index: usize) -> String {
    format!("epoch-{epoch}.shard-{shard_index}.json")
}

/// Parse an exchange-delta file name back into `(epoch, shard_index)`;
/// `None` for anything that is not a delta (staging debris, foreign files).
pub fn parse_exchange_delta_name(name: &str) -> Option<(usize, usize)> {
    let rest = name.strip_prefix("epoch-")?.strip_suffix(".json")?;
    let (epoch, shard) = rest.split_once(".shard-")?;
    Some((epoch.parse().ok()?, shard.parse().ok()?))
}

/// Path of one shard's delta for one epoch inside a per-strategy exchange
/// directory.
fn exchange_delta_path(dir: &Path, epoch: usize, shard_index: usize) -> PathBuf {
    dir.join(exchange_delta_name(epoch, shard_index))
}

/// Stable machine-recognizable prefix of every exchange peer-wait timeout
/// error. The launcher keys on it (via [`ExchangeWaitTimeout::matches`])
/// to classify a failed shard as *restartable-with-cause* — the peer it
/// waited on died, not the shard itself — instead of burning the ordinary
/// restart budget blind.
pub const EXCHANGE_TIMEOUT_PREFIX: &str = "exchange wait timed out";

/// Process exit code a child exits with when a run fails on an exchange
/// peer-wait timeout (BSD `EX_TEMPFAIL`): the condition is transient — the
/// missing peer can still be restarted or its batch re-dispatched — so the
/// supervisor treats it separately from a real failure.
pub const EXCHANGE_TIMEOUT_EXIT: i32 = 75;

/// Typed description of an exchange peer-wait timeout: exactly which peer
/// slice's delta, for which epoch of which strategy, never appeared.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExchangeWaitTimeout {
    /// Strategy whose exchange directory was being waited on.
    pub strategy: String,
    /// Epoch index of the missing delta.
    pub epoch: usize,
    /// Peer slice index (shard index, or batch index under elastic
    /// scheduling) that never published.
    pub shard: usize,
    /// How long this slice waited, in milliseconds.
    pub waited_ms: u64,
    /// Path the delta was expected to appear at.
    pub path: PathBuf,
}

impl ExchangeWaitTimeout {
    /// Does an error string describe an exchange peer-wait timeout?
    /// (Stable across releases: tested against [`EXCHANGE_TIMEOUT_PREFIX`].)
    pub fn matches(msg: &str) -> bool {
        msg.starts_with(EXCHANGE_TIMEOUT_PREFIX)
    }
}

impl std::fmt::Display for ExchangeWaitTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{EXCHANGE_TIMEOUT_PREFIX}: no delta from peer slice {} for epoch {} of \
             strategy {:?} after {}ms (expected at {}) — the peer died without being \
             restarted, or the slices disagree about --shards / --batch-count / \
             --exchange-epoch / --exchange-dir",
            self.shard,
            self.epoch,
            self.strategy,
            self.waited_ms,
            self.path.display()
        )
    }
}

/// Block until a peer's exchange delta appears (writes are atomic renames,
/// so existence implies a complete file). On timeout the error names the
/// exact missing delta — strategy, epoch, peer slice — behind the stable
/// [`EXCHANGE_TIMEOUT_PREFIX`].
fn wait_for_exchange_file(
    path: &Path,
    ex: &ExchangeOptions,
    strategy: &str,
    epoch: usize,
    peer: usize,
) -> Result<(), String> {
    // Measured with `elapsed() >= timeout`, not a precomputed
    // `Instant + Duration` deadline: the addition panics on overflow for
    // very large `wait_timeout_ms` values.
    let start = std::time::Instant::now();
    let timeout = std::time::Duration::from_millis(ex.wait_timeout_ms);
    while !path.exists() {
        if start.elapsed() >= timeout {
            return Err(ExchangeWaitTimeout {
                strategy: strategy.to_string(),
                epoch,
                shard: peer,
                waited_ms: ex.wait_timeout_ms,
                path: path.to_path_buf(),
            }
            .to_string());
        }
        std::thread::sleep(std::time::Duration::from_millis(ex.poll_ms.max(1)));
    }
    Ok(())
}

/// Publish one epoch delta. Deltas are deterministic (a cold fold of the
/// window's observations), so an already-present file — written by the
/// pre-crash process, or by a concurrently resuming peer — must agree; a
/// disagreeing file means the exchange dir belongs to a different run and
/// continuing would poison every peer.
fn write_exchange_delta(path: &Path, delta: &SkillStore) -> Result<(), String> {
    if path.exists() {
        let existing = SkillStore::load(path)?;
        if existing != *delta {
            return Err(format!(
                "exchange delta {} disagrees with this run's checkpointed cells; the \
                 exchange dir was produced by a different run — refusing to continue",
                path.display()
            ));
        }
        return Ok(());
    }
    delta
        .save(path)
        .map_err(|e| format!("writing exchange delta {}: {e}", path.display()))
}

/// Test-only crash hook for the launcher tests and the CI `launch-smoke`
/// job: with `KS_TEST_CRASH_AFTER=<n>` and `KS_TEST_CRASH_MARKER=<path>`
/// both set, the process hard-exits (code 86) immediately after appending
/// its n-th checkpoint line — once per `<path>.shard-<index>` marker file,
/// so the relaunched process resumes and runs to completion.
struct CrashHook {
    after: usize,
    marker: PathBuf,
    appended: usize,
}

impl CrashHook {
    fn from_env(shard_index: usize) -> Option<CrashHook> {
        let after: usize = std::env::var("KS_TEST_CRASH_AFTER").ok()?.parse().ok()?;
        let marker = std::env::var("KS_TEST_CRASH_MARKER").ok()?;
        if marker.is_empty() || after == 0 {
            return None;
        }
        Some(CrashHook {
            after,
            marker: PathBuf::from(format!("{marker}.shard-{shard_index}")),
            appended: 0,
        })
    }

    fn tick(&mut self) {
        self.appended += 1;
        if self.appended >= self.after && !self.marker.exists() {
            let _ = std::fs::write(&self.marker, "crashed\n");
            crate::log_warn!(
                "KS_TEST_CRASH_AFTER: simulating a hard kill after {} checkpoint append(s)",
                self.appended
            );
            std::process::exit(86);
        }
    }
}

/// Run one strategy's cells, in deterministic (task-major, seed-minor)
/// result order. See module docs for the orchestration contract.
pub fn run_strategy(
    tasks: &[Task],
    strategy: &Strategy,
    cfg: &LoopConfig,
    seeds: &[u64],
    workers: usize,
    opts: &SuiteOptions,
) -> Result<Vec<TaskResult>, String> {
    // Cell matrix, task-major (matches the v1 fan-out order).
    let cells: Vec<(usize, u64)> = (0..tasks.len())
        .flat_map(|t| seeds.iter().map(move |s| (t, *s)))
        .collect();
    if let Some(s) = &opts.shard {
        s.validate()?;
    }
    if let Some(b) = &opts.batch {
        b.validate()?;
    }
    if opts.shard.is_some() && opts.batch.is_some() {
        return Err(
            "--shards/--shard-index and --batch-index/--batch-count are mutually \
             exclusive slicing modes"
                .to_string(),
        );
    }
    if let Some(ex) = &opts.exchange {
        if ex.epoch_cells == 0 {
            return Err("--exchange-epoch must be >= 1".to_string());
        }
    }
    let n_cells = cells.len();
    let owns = |ci: usize| match opts.batch {
        Some(b) => b.owns(ci, n_cells),
        None => opts.shard.map_or(true, |s| s.owns(ci)),
    };

    // ---- checkpoint directory ------------------------------------------
    let run_dir = match &opts.run_dir {
        Some(path) => Some(RunDir::open(path).map_err(|e| format!("opening run dir: {e}"))?),
        None => None,
    };
    // Both the run dir and the memory dir own a `skills.json` (checkpoint
    // fold vs. live long-term store); sharing one directory would have them
    // silently clobber each other, so refuse before writing anything.
    if let (Some(rd), Some(mem)) = (&run_dir, &cfg.memory_dir) {
        let same = match (std::fs::canonicalize(rd.root()), std::fs::canonicalize(mem)) {
            (Ok(a), Ok(b)) => a == b,
            _ => rd.root() == mem.as_path(),
        };
        if same {
            return Err(format!(
                "--run-dir and --memory-dir must be different directories \
                 ({}): both write a skills.json there",
                rd.root().display()
            ));
        }
    }
    let task_ids: Vec<&str> = tasks.iter().map(|t| t.id.as_str()).collect();
    let expected = RunManifest {
        n_tasks: tasks.len(),
        seeds: seeds.to_vec(),
        rt: cfg.rt,
        at: cfg.at,
        fingerprint: RunManifest::fingerprint_tasks(&task_ids),
        shards: opts.shard.map_or(1, |s| s.count),
        shard_index: opts.shard.map_or(0, |s| s.index),
        exchange_epoch: opts.exchange.as_ref().map_or(0, |ex| ex.epoch_cells),
        exchange_adaptive: opts.exchange.as_ref().is_some_and(|ex| ex.adaptive),
        lease_batches: opts.batch.map_or(0, |b| b.count),
        lease_batch: opts.batch.map_or(0, |b| b.index),
        device: cfg.dev.name.to_string(),
        chaos: cfg.chaos.as_ref().map(|c| c.render()).unwrap_or_default(),
    };
    let mut restored: std::collections::BTreeMap<usize, TaskResult> = Default::default();
    // Fold of every checkpointed cell's observations (all strategies), so
    // `merge` can combine shards' stores without re-running anything.
    // Rebuilt from the checkpoint on open (never loaded) and saved once
    // after dispatch: a killed run's on-disk copy may lag results.jsonl,
    // but reopening — or `merge`, which derives the authoritative store
    // from the cells — always reconciles it.
    let mut run_store: Option<SkillStore> = None;
    if let Some(rd) = &run_dir {
        match rd.read_manifest()? {
            Some(m) if m != expected => {
                return Err(format!(
                    "run dir {} was written for a different matrix or shard \
                     (manifest {m:?} != expected {expected:?}); refusing to mix results",
                    rd.root().display()
                ));
            }
            Some(_) => {}
            None => rd
                .write_manifest(&expected)
                .map_err(|e| format!("writing manifest: {e}"))?,
        }

        let on_disk = rd.load().map_err(|e| format!("loading checkpoint: {e}"))?;
        let mut rs = SkillStore::new();
        for result in on_disk.values() {
            rs.merge(&result.skill_obs);
        }
        rs.save(&rd.skills_path())
            .map_err(|e| format!("writing run-dir skill store: {e}"))?;
        run_store = Some(rs);

        let mut index = std::collections::BTreeMap::new();
        for (ci, &(ti, seed)) in cells.iter().enumerate() {
            index.insert((tasks[ti].id.as_str(), seed), ci);
        }
        let mut mine = 0usize;
        for (key, result) in on_disk {
            if key.strategy != strategy.name {
                continue;
            }
            mine += 1;
            match index.get(&(key.task_id.as_str(), key.seed)) {
                Some(&ci) if owns(ci) => {
                    restored.insert(ci, result);
                }
                Some(_) => crate::log_warn!(
                    "checkpoint cell ({}, {}, {}) belongs to another shard; ignoring",
                    key.strategy,
                    key.task_id,
                    key.seed
                ),
                None => crate::log_warn!(
                    "checkpoint cell ({}, {}, {}) is not in this matrix; ignoring",
                    key.strategy,
                    key.task_id,
                    key.seed
                ),
            }
        }
        if !opts.resume && mine > 0 {
            return Err(format!(
                "run dir {} already holds {mine} result(s) for strategy {:?}; \
                 pass resume (--resume) or use a fresh directory",
                rd.root().display(),
                strategy.name
            ));
        }
    }

    // ---- persistent long-term memory -----------------------------------
    let live_path = cfg.memory_dir.as_ref().map(|d| d.join("skills.json"));
    let snapshot: Option<Arc<SkillStore>> = if let Some(s) = &cfg.skills {
        Some(s.clone())
    } else if let Some(rd) = run_dir
        .as_ref()
        .filter(|rd| opts.resume && rd.memory_snapshot_path(strategy.name).exists())
    {
        // Resume: warm-start from the snapshot this strategy's interrupted
        // run took, so the remaining cells see exactly what the finished
        // cells saw (snapshots are per-strategy: in a matrix run, later
        // strategies start from a live store that already includes earlier
        // strategies' merges).
        Some(Arc::new(SkillStore::load(&rd.memory_snapshot_path(strategy.name))?))
    } else if let Some(path) = &live_path {
        Some(Arc::new(SkillStore::load(path)?))
    } else {
        None
    };
    if let (Some(rd), Some(snap)) = (&run_dir, &snapshot) {
        let snap_path = rd.memory_snapshot_path(strategy.name);
        if !snap_path.exists() {
            snap.save(&snap_path)
                .map_err(|e| format!("writing memory snapshot: {e}"))?;
        }
    }
    // The live store absorbs observations as cells finish. It opens in the
    // v4 segmented layout from the current on-disk state (on resume that
    // already includes the interrupted run's merges; restored cells are
    // NOT re-merged).
    //
    // Fold epoch: this run's observations are stamped with generation
    // snapshot+1, derived from the warm-start snapshot rather than the
    // live store itself so a resumed run reuses the interrupted run's
    // epoch (the on-disk store already carries the bump — `advance_to` is
    // then a no-op, so no segment rotates) — fold order and kill points
    // can never change a stamp. Advancing the clock per strategy-suite run
    // is what ages stats that stop being re-observed; under the segmented
    // layout it also rotates the previous epochs' head into an immutable
    // segment instead of rewriting accumulated history at every save.
    let mut live_store: Option<SegmentedSkillStore> = match &cfg.memory_dir {
        Some(dir) => Some(SegmentedSkillStore::open(dir)?),
        None => None,
    };
    if let Some(store) = live_store.as_mut() {
        let base_gen = snapshot
            .as_deref()
            .map(|s| s.generation)
            .unwrap_or_else(|| store.generation());
        let rotated = store
            .advance_to(store.generation().max(base_gen + 1))
            .map_err(|e| format!("rotating skill store head: {e}"))?;
        if rotated {
            // Persist immediately so the manifest references the fresh
            // segment even if this run dies before its first fold.
            store
                .save()
                .map_err(|e| format!("saving skill store manifest: {e}"))?;
        }
    }
    if let Some(dir) = &cfg.memory_dir {
        // Make the memory directory self-describing: curated KB next to the
        // learned store.
        let kb_path = dir.join("kb.json");
        if !kb_path.exists() {
            std::fs::create_dir_all(dir).map_err(|e| format!("creating memory dir: {e}"))?;
            std::fs::write(&kb_path, format!("{}\n", kb_content::export_kb()))
                .map_err(|e| format!("writing kb export: {e}"))?;
        }
    }

    let mut cfg_run = cfg.clone();
    cfg_run.skills = snapshot.clone();

    // ---- dispatch -------------------------------------------------------
    // The matrix is cut into epoch windows over the *global* flat cell
    // index; without exchange the whole matrix is a single window, which
    // preserves the pre-exchange scheduler's behavior (and bytes) exactly.
    let shard = opts.shard.unwrap_or(Shard { index: 0, count: 1 });
    // The slice index deltas are published under (and crash markers named
    // by): the shard index, or the batch index under elastic scheduling.
    let slice_index = opts.batch.map_or(shard.index, |b| b.index);
    let (epoch_len, adaptive) = opts
        .exchange
        .as_ref()
        .map_or((cells.len().max(1), false), |ex| {
            (ex.epoch_cells, ex.adaptive)
        });
    let windows = exchange_windows(cells.len(), epoch_len, adaptive);
    // The peer slices whose deltas gate a window: every slice owning cells
    // in it. Round-robin shards overlap every window (all peers — the
    // pre-elastic behavior, bit for bit); contiguous batches overlap few,
    // so a batch nobody claimed yet can never deadlock a window it has no
    // cells in.
    let window_peers = |lo: usize, hi: usize| -> Vec<usize> {
        match opts.batch {
            None => (0..shard.count).collect(),
            Some(b) => (0..b.count)
                .filter(|&k| {
                    let (blo, bhi) = batch_bounds(k, b.count, n_cells);
                    blo < hi && bhi > lo
                })
                .collect(),
        }
    };
    let exchange_dir = match &opts.exchange {
        Some(ex) => {
            let dir = ex.dir.join(strategy_slug(strategy.name));
            std::fs::create_dir_all(&dir)
                .map_err(|e| format!("creating exchange dir {}: {e}", dir.display()))?;
            Some(dir)
        }
        None => None,
    };
    // The snapshot cells retrieve against. Exchange runs fold every shard's
    // earlier-epoch deltas in at each boundary; otherwise it stays the
    // run-start snapshot for the whole run.
    let mut working: Arc<SkillStore> =
        snapshot.clone().unwrap_or_else(|| Arc::new(SkillStore::new()));
    if opts.exchange.is_some() {
        cfg_run.skills = Some(working.clone());
    }
    // Epochs whose deltas (from every shard) are already folded into
    // `working`. Folding is caught up lazily, right before the first window
    // that actually has cells to run, so resume fast-forward and stop_after
    // never block on peers they no longer need.
    let mut folded_through = 0usize;

    let mut crash_hook = CrashHook::from_env(slice_index);
    let mut budget = opts.stop_after.map(|s| s.saturating_sub(restored.len()));
    let mut all_fresh: std::collections::BTreeMap<usize, TaskResult> = Default::default();
    let mut sink_err: Option<String> = None;

    for (w, &(lo, hi)) in windows.iter().enumerate() {

        // This shard's unfinished cells in the window, budget-capped.
        let mut pending: Vec<usize> = (lo..hi)
            .filter(|&ci| owns(ci) && !restored.contains_key(&ci))
            .collect();
        let mut truncated = false;
        if let Some(b) = budget.as_mut() {
            if pending.len() > *b {
                pending.truncate(*b);
                truncated = true;
            }
            *b -= pending.len();
        }

        if let (Some(ex), Some(dir)) = (&opts.exchange, &exchange_dir) {
            if !pending.is_empty() {
                // Epoch boundary: fold every shard's deltas for the epochs
                // before this window into the working snapshot.
                // `merge_store` is commutative and associative at the bit
                // level, so fold order cannot matter; *waiting* for peers
                // is what makes the snapshot a pure function of the matrix
                // rather than of timing.
                while folded_through < w {
                    let mut folded = (*working).clone();
                    let (flo, fhi) = windows[folded_through];
                    for peer in window_peers(flo, fhi) {
                        let path = exchange_delta_path(dir, folded_through, peer);
                        wait_for_exchange_file(&path, ex, strategy.name, folded_through, peer)?;
                        folded.merge_store(&SkillStore::load(&path)?);
                    }
                    working = Arc::new(folded);
                    folded_through += 1;
                }
                cfg_run.skills = Some(working.clone());
            }
        }

        let fresh = pool::run_streaming(
            &pending,
            workers,
            |_, &ci| {
                let (ti, seed) = cells[ci];
                let mut c = cfg_run.clone();
                c.run_seed = seed;
                run_task(&tasks[ti], strategy, &c)
            },
            |ip, r| {
                let (ti, seed) = cells[pending[ip]];
                if let Some(rd) = &run_dir {
                    let key = CellKey {
                        strategy: strategy.name.to_string(),
                        task_id: tasks[ti].id.clone(),
                        seed,
                    };
                    if let Err(e) = rd.append(&key, r) {
                        sink_err.get_or_insert(format!("appending checkpoint: {e}"));
                    }
                    if let Some(hook) = crash_hook.as_mut() {
                        hook.tick();
                    }
                }
                if let Some(store) = live_store.as_mut() {
                    // Merged per cell, serialized at the window boundary
                    // below: `skills.json` rewrites are checkpoint-boundary
                    // work, not per-round/per-cell work.
                    store.merge(&r.skill_obs);
                }
                if let Some(rs) = run_store.as_mut() {
                    // Folded per cell, saved once after the dispatch loop:
                    // the on-disk copy is only advisory (it is rebuilt from
                    // the checkpoint on open, and `merge` derives the
                    // authoritative store from the cells), so per-cell
                    // rewrites would be wasted I/O.
                    rs.merge(&r.skill_obs);
                }
            },
        );
        if let Some(e) = sink_err.take() {
            return Err(e);
        }
        // Window boundary: one atomic `skills.json` rewrite for everything
        // the window merged. A kill can now lose at most a window of
        // *live-store* lag (the checkpoint is still per-cell, and a crashed
        // cell's observations were already lost under per-cell saving too,
        // since the crash hook fires before the live merge); the byte gates
        // never compare live stores — launch/worker refuse `--memory-dir`.
        if !pending.is_empty() {
            if let Some(store) = live_store.as_mut() {
                store
                    .save()
                    .map_err(|e| format!("saving skill store: {e}"))?;
            }
        }
        for (ci, r) in pending.iter().copied().zip(fresh) {
            all_fresh.insert(ci, r);
        }

        if let Some(dir) = &exchange_dir {
            // Publish this shard's epoch delta once every owned cell in the
            // window has a result. A stop_after kill leaves it unwritten;
            // resume recomputes it from the restored checkpoint cells, so a
            // crashed shard's peers unblock as soon as it is relaunched.
            let own: Vec<usize> = (lo..hi).filter(|&ci| owns(ci)).collect();
            let complete = own
                .iter()
                .all(|ci| restored.contains_key(ci) || all_fresh.contains_key(ci));
            // Batches skip windows they own no cells in — no peer waits on
            // them there (see `window_peers`). Shards publish even empty
            // windows: every shard gates every window in round-robin mode.
            if complete && (opts.batch.is_none() || !own.is_empty()) {
                let delta = SkillStore::from_observations(own.iter().flat_map(|ci| {
                    restored
                        .get(ci)
                        .or_else(|| all_fresh.get(ci))
                        .map(|r| r.skill_obs.as_slice())
                        .unwrap_or(&[])
                        .iter()
                }));
                write_exchange_delta(&exchange_delta_path(dir, w, slice_index), &delta)?;
            }
        }
        if truncated {
            break;
        }
    }
    if let (Some(rs), Some(rd)) = (&run_store, &run_dir) {
        rs.save(&rd.skills_path())
            .map_err(|e| format!("saving run-dir skill store: {e}"))?;
    }

    // ---- assemble in matrix order ---------------------------------------
    let mut out = Vec::with_capacity(restored.len() + all_fresh.len());
    for ci in 0..cells.len() {
        if let Some(r) = restored.remove(&ci) {
            out.push(r);
        } else if let Some(r) = all_fresh.remove(&ci) {
            out.push(r);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::bench_suite;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ks-sched-{tag}-{}", std::process::id()))
    }

    fn slice(n: usize) -> Vec<Task> {
        bench_suite::level_suite(42, 1).into_iter().take(n).collect()
    }

    #[test]
    fn stop_after_completes_a_prefix_and_resume_finishes_it() {
        let dir = tmp_dir("resume");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(4);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();

        let full =
            run_strategy(&tasks, &strat, &cfg, &[0, 1], 4, &SuiteOptions::default()).unwrap();
        assert_eq!(full.len(), 8);

        let mut opts = SuiteOptions::in_dir(&dir);
        opts.stop_after = Some(3);
        let partial = run_strategy(&tasks, &strat, &cfg, &[0, 1], 4, &opts).unwrap();
        assert_eq!(partial.len(), 3);

        // Fresh (non-resume) reuse of a dirty dir is refused.
        let err = run_strategy(&tasks, &strat, &cfg, &[0, 1], 4, &SuiteOptions::in_dir(&dir));
        assert!(err.is_err());

        let resumed =
            run_strategy(&tasks, &strat, &cfg, &[0, 1], 4, &SuiteOptions::resumed(&dir)).unwrap();
        assert_eq!(resumed.len(), 8);
        for (a, b) in full.iter().zip(&resumed) {
            assert_eq!(a.task_id, b.task_id);
            assert_eq!(a.best_speedup, b.best_speedup, "{}", a.task_id);
            assert_eq!(a.rounds.len(), b.rounds.len());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatched_matrix_is_refused() {
        let dir = tmp_dir("mismatch");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(3);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        run_strategy(&tasks, &strat, &cfg, &[0], 2, &SuiteOptions::in_dir(&dir)).unwrap();
        let other = slice(2);
        let err = run_strategy(&other, &strat, &cfg, &[0], 2, &SuiteOptions::resumed(&dir));
        assert!(err.is_err(), "different matrix must be refused");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_runs_only_its_slice_and_slices_union_to_the_full_run() {
        let tasks = slice(3);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        let seeds = [0u64, 1];
        let full = run_strategy(&tasks, &strat, &cfg, &seeds, 4, &SuiteOptions::default()).unwrap();
        assert_eq!(full.len(), 6);

        for count in [2usize, 3] {
            let mut seen = 0usize;
            for index in 0..count {
                let opts = SuiteOptions::default().with_shard(index, count);
                let part = run_strategy(&tasks, &strat, &cfg, &seeds, 4, &opts).unwrap();
                let owned: Vec<usize> =
                    (0..6).filter(|&ci| Shard { index, count }.owns(ci)).collect();
                assert_eq!(part.len(), owned.len(), "shard {index}/{count}");
                for (r, &ci) in part.iter().zip(&owned) {
                    assert_eq!(r.task_id, full[ci].task_id, "shard {index}/{count}");
                    assert_eq!(r.best_speedup, full[ci].best_speedup, "shard {index}/{count}");
                    assert_eq!(r.rounds, full[ci].rounds, "shard {index}/{count}");
                }
                seen += part.len();
            }
            assert_eq!(seen, 6, "{count} shards must exactly cover the matrix");
        }
    }

    #[test]
    fn exchange_windows_fixed_and_adaptive_schedules() {
        assert_eq!(exchange_windows(5, 2, false), vec![(0, 2), (2, 4), (4, 5)]);
        assert_eq!(exchange_windows(0, 2, false), Vec::<(usize, usize)>::new());
        assert_eq!(
            exchange_windows(20, 2, true),
            vec![(0, 2), (2, 6), (6, 14), (14, 20)],
            "adaptive windows double: 2, 4, 8, then clipped"
        );
        // Degenerate epoch length is clamped rather than looping forever.
        assert_eq!(exchange_windows(3, 0, false), vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn batch_bounds_are_a_balanced_exact_cover() {
        for n in [0usize, 1, 5, 17] {
            for count in [1usize, 2, 3, 7] {
                let mut seen = 0usize;
                let mut prev_hi = 0usize;
                for k in 0..count {
                    let (lo, hi) = batch_bounds(k, count, n);
                    assert_eq!(lo, prev_hi, "batches must tile contiguously");
                    assert!(hi >= lo);
                    assert!(hi - lo <= n / count + 1, "balanced to within one cell");
                    seen += hi - lo;
                    prev_hi = hi;
                }
                assert_eq!(seen, n, "{count} batches must exactly cover {n} cells");
            }
        }
    }

    #[test]
    fn batch_runs_only_its_slice_and_batches_union_to_the_full_run() {
        let tasks = slice(3);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        let seeds = [0u64, 1];
        let full = run_strategy(&tasks, &strat, &cfg, &seeds, 4, &SuiteOptions::default()).unwrap();
        assert_eq!(full.len(), 6);

        for count in [2usize, 4] {
            let mut seen = 0usize;
            for index in 0..count {
                let opts = SuiteOptions::default().with_batch(index, count);
                let part = run_strategy(&tasks, &strat, &cfg, &seeds, 4, &opts).unwrap();
                let (lo, hi) = batch_bounds(index, count, 6);
                assert_eq!(part.len(), hi - lo, "batch {index}/{count}");
                for (r, ci) in part.iter().zip(lo..hi) {
                    assert_eq!(r.task_id, full[ci].task_id, "batch {index}/{count}");
                    assert_eq!(r.best_speedup, full[ci].best_speedup, "batch {index}/{count}");
                    assert_eq!(r.rounds, full[ci].rounds, "batch {index}/{count}");
                }
                seen += part.len();
            }
            assert_eq!(seen, 6, "{count} batches must exactly cover the matrix");
        }
    }

    #[test]
    fn batch_and_shard_slicing_are_mutually_exclusive() {
        let tasks = slice(1);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        let opts = SuiteOptions::default().with_shard(0, 2).with_batch(0, 2);
        let err = run_strategy(&tasks, &strat, &cfg, &[0], 1, &opts).unwrap_err();
        assert!(err.contains("mutually exclusive"), "{err}");
        let bad = SuiteOptions::default().with_batch(3, 2);
        assert!(run_strategy(&tasks, &strat, &cfg, &[0], 1, &bad).is_err());
    }

    #[test]
    fn exchange_timeout_error_names_the_missing_peer_delta() {
        // Batch 1 of 2 needs batch 0's window-0 delta before its own cells;
        // nobody ever publishes it, so the wait must fail with the typed,
        // prefix-stable error naming (strategy, epoch, slice).
        let dir = tmp_dir("ex-timeout");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(2);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        let mut opts = SuiteOptions::default().with_batch(1, 2);
        opts.exchange = Some(ExchangeOptions {
            dir: dir.clone(),
            epoch_cells: 1,
            wait_timeout_ms: 60,
            poll_ms: 5,
            adaptive: false,
        });
        let err = run_strategy(&tasks, &strat, &cfg, &[0, 1], 2, &opts).unwrap_err();
        assert!(ExchangeWaitTimeout::matches(&err), "{err}");
        assert!(err.contains("epoch 0") && err.contains("peer slice 0"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn batches_with_exchange_match_the_single_process_bytes() {
        // Two batches exchanging through a shared dir, run to completion in
        // dependency order (batch 0 first publishes the windows batch 1
        // waits on): the union must match the unsliced run exactly.
        let dir = tmp_dir("batch-ex");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(2);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        let seeds = [0u64, 1];
        // The reference uses the same exchange schedule (the snapshot a
        // cell sees is a function of the matrix AND the epoch cut).
        let mut full_opts = SuiteOptions::default();
        full_opts.exchange = Some(ExchangeOptions::new(dir.join("ex-ref"), 2));
        let full = run_strategy(&tasks, &strat, &cfg, &seeds, 4, &full_opts).unwrap();

        let mut parts = Vec::new();
        for index in 0..2 {
            let mut opts = SuiteOptions::default().with_batch(index, 2);
            opts.exchange = Some(ExchangeOptions::new(dir.join("ex"), 2));
            parts.push(run_strategy(&tasks, &strat, &cfg, &seeds, 4, &opts).unwrap());
        }
        let merged: Vec<_> = parts.into_iter().flatten().collect();
        assert_eq!(merged.len(), full.len());
        for (a, b) in full.iter().zip(&merged) {
            assert_eq!(a.task_id, b.task_id);
            assert_eq!(a.best_speedup, b.best_speedup, "{}", a.task_id);
            assert_eq!(a.rounds, b.rounds, "{}", a.task_id);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn invalid_shard_is_refused() {
        let tasks = slice(1);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        for (index, count) in [(0usize, 0usize), (2, 2), (5, 3)] {
            let opts = SuiteOptions::default().with_shard(index, count);
            assert!(
                run_strategy(&tasks, &strat, &cfg, &[0], 1, &opts).is_err(),
                "shard {index}/{count} must be rejected"
            );
        }
    }

    #[test]
    fn resume_with_different_shard_settings_is_refused() {
        let dir = tmp_dir("shard-mix");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(2);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        let opts = SuiteOptions::in_dir(&dir).with_shard(0, 2);
        run_strategy(&tasks, &strat, &cfg, &[0], 2, &opts).unwrap();
        // Same dir, different shard assignment (or unsharded): refused.
        let other = SuiteOptions::resumed(&dir).with_shard(1, 2);
        assert!(run_strategy(&tasks, &strat, &cfg, &[0], 2, &other).is_err());
        let unsharded = SuiteOptions::resumed(&dir);
        assert!(run_strategy(&tasks, &strat, &cfg, &[0], 2, &unsharded).is_err());
        // The matching shard resumes cleanly.
        let same = SuiteOptions::resumed(&dir).with_shard(0, 2);
        assert!(run_strategy(&tasks, &strat, &cfg, &[0], 2, &same).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_dir_equal_to_memory_dir_is_refused() {
        // Both dirs own a skills.json (checkpoint fold vs. live long-term
        // store); sharing one path would silently clobber the memory.
        let dir = tmp_dir("collide");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(1);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig {
            memory_dir: Some(dir.clone()),
            ..LoopConfig::default()
        };
        let err = run_strategy(&tasks, &strat, &cfg, &[0], 1, &SuiteOptions::in_dir(&dir));
        assert!(err.is_err(), "run_dir == memory_dir must be rejected");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_dir_skill_store_tracks_checkpointed_observations() {
        let dir = tmp_dir("rundir-skills");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(2);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        let results =
            run_strategy(&tasks, &strat, &cfg, &[0], 2, &SuiteOptions::in_dir(&dir)).unwrap();
        let store = SkillStore::load(&dir.join("skills.json")).unwrap();
        let expected: u64 = results.iter().map(|r| r.skill_obs.len() as u64).sum();
        assert_eq!(store.observations, expected);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn memory_dir_persists_skills_and_kb() {
        let dir = tmp_dir("memdir");
        let mem = dir.join("memory");
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = slice(3);
        let strat = baselines::kernelskill();
        let cfg = LoopConfig {
            memory_dir: Some(mem.clone()),
            ..LoopConfig::default()
        };
        run_strategy(&tasks, &strat, &cfg, &[0], 2, &SuiteOptions::default()).unwrap();
        let store = SkillStore::load(&mem.join("skills.json")).unwrap();
        assert!(store.observations > 0, "L1 slice should produce observations");
        assert!(mem.join("kb.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
