//! Run-dir transports: the cross-machine synchronization layer under
//! `launch --manifest` / `worker`.
//!
//! A *transport* is the channel one worker machine shares with the
//! coordinator: the worker publishes its run-dir artifacts (checkpoint
//! lines, manifest, skill store, warm-start snapshots, exchange deltas)
//! into its transport root, and the coordinator pulls them into local
//! mirrors it feeds to the ordinary [`MergeWatcher`] — so the distributed
//! merge machinery never learns that a network was involved.
//!
//! Visibility contract (what makes the tail-follow safe over any medium):
//!
//!   * **Whole-file publishes are atomic.** [`RunDirTransport::publish`]
//!     stages the bytes and renames them into place; a reader can never
//!     observe a partially transferred file. An interrupted transfer leaves
//!     only staging debris that `list`/`fetch` ignore.
//!   * **Checkpoints are published at newline boundaries only.** The push
//!     engine ([`ShardPush`]) publishes `results.jsonl` growth as
//!     append-only *segment files* cut at its last newline
//!     (`results.seg-<offset>.jsonl`, each an immutable slice starting at
//!     the byte offset its name encodes), so the pulled mirror can only
//!     ever end at a complete line — exactly the torn-tail contract
//!     `MergeWatcher` already enforces for local concurrent appends — and
//!     each growth step moves only the new bytes (whole-file republish per
//!     step was O(n²) traffic at object-store scale).
//!   * **First publish wins.** [`RunDirTransport::publish_excl`] is the
//!     claim primitive under elastic lease scheduling: of any number of
//!     racing publishers of one path, exactly one succeeds and the rest
//!     observe the loss — never a torn or last-writer-wins file.
//!   * **`complete` is published last**, after every byte it vouches for,
//!     and the pull engine ([`ShardPull`]) re-reads the checkpoint *after*
//!     observing the marker — so a mirror carrying `complete` is guaranteed
//!     to hold the worker's whole slice.
//!
//! Two implementations ship: [`LocalFs`] (a shared filesystem; zero-copy —
//! it exposes its paths directly so workers stream straight into the root
//! and the coordinator tails it in place) and [`MirrorDir`] (an
//! object-store-shaped backend that only speaks `list`/`fetch`/`publish`
//! with staged atomic writes — the stand-in for S3/GCS/rsync, fully
//! testable in CI without a network).
//!
//! The worker fleet is described by a [`WorkerManifest`] (`--manifest`),
//! in one of two shapes. **Static**: worker ids, the contiguous shard
//! range each runs, and each worker's transport; validation is strict —
//! duplicate ids, overlapping or gapped shard ranges, and unknown
//! transport kinds are refused before anything spawns. **Elastic**
//! (`"lease"` + `"total_batches"` instead of ranges): nobody is assigned
//! anything up front — the matrix is cut into contiguous cell batches and
//! workers *claim* them at run time by atomically publishing lease files
//! on a lease transport every machine shares (see [`Lease`]), so a
//! heterogeneous fleet finishes together instead of waiting on its
//! slowest member.
//!
//! On-transport layout under each worker's root (elastic runs use
//! `up/batch-<k>/` run-dir mirrors instead of `up/shard-<i>/`, and the
//! shared lease root additionally holds `leases/`):
//!
//! ```text
//! <root>/
//!   up/shard-<i>/...              worker -> coordinator: mirror of shard i's run dir
//!   up/batch-<k>/...              (elastic) mirror of claimed batch k's run dir
//!   up/<dir>/results.seg-<o>.jsonl  immutable checkpoint segment starting at byte <o>
//!   up/exchange/<slug>/<delta>    worker -> coordinator: its own slices' epoch deltas
//!   down/exchange/<slug>/<delta>  coordinator -> worker: every peer's epoch deltas
//!   .staging/                     atomic-publish scratch (never read)
//! <lease root>/                   (elastic; shared by the whole fleet)
//!   leases/batch-<k>.attempt-<a>.json     claim + progress heartbeat for one attempt
//!   leases/batch-<k>.attempt-<a>.expired  coordinator re-dispatch marker
//! ```
//!
//! The byte-determinism consequence — worker placement and sync timing
//! cannot change a single output byte — is specified as invariants 11-13
//! in `docs/memory-formats.md` and pinned by `tests/distributed.rs` plus
//! the CI `multi-node-smoke` job.
//!
//! [`MergeWatcher`]: super::merge::MergeWatcher

use std::collections::{BTreeMap, BTreeSet};
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::checkpoint::RunDir;
use super::scheduler::parse_exchange_delta_name;
use crate::memory::long_term::SkillStore;
use crate::util::json::Json;

/// File name of the per-cell checkpoint inside a (mirrored) run dir.
const RESULTS: &str = "results.jsonl";
/// File name of the matrix-shape manifest inside a (mirrored) run dir.
const MANIFEST: &str = "manifest.json";
/// File name of the per-dir skill-store fold inside a (mirrored) run dir.
const SKILLS: &str = "skills.json";

/// Relative transport directory a worker publishes shard `i`'s run dir to.
pub fn up_shard_rel(shard_index: usize) -> String {
    format!("up/shard-{shard_index}")
}

/// Relative transport directory an elastic worker publishes claimed batch
/// `k`'s run dir to.
pub fn up_batch_rel(batch: usize) -> String {
    format!("up/batch-{batch}")
}

/// Batch index encoded in an elastic `up/` mirror directory name
/// (`batch-<k>`), if it is one.
pub fn parse_up_batch_name(name: &str) -> Option<usize> {
    name.strip_prefix("batch-")?.parse().ok()
}

/// Name of the immutable checkpoint segment starting at byte `start` of
/// `results.jsonl`. Zero-padded so lexicographic listing order is offset
/// order.
pub fn segment_name(start: u64) -> String {
    format!("results.seg-{start:020}.jsonl")
}

/// Start offset encoded in a checkpoint segment file name, if it is one.
pub fn parse_segment_name(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("results.seg-")?.strip_suffix(".jsonl")?;
    if digits.len() != 20 || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Relative transport directory a worker publishes its own exchange deltas
/// to.
pub const UP_EXCHANGE: &str = "up/exchange";

/// Relative transport directory the coordinator re-publishes the fleet's
/// exchange deltas into for one worker to pull.
pub const DOWN_EXCHANGE: &str = "down/exchange";

/// Join a validated relative transport path onto a root. Rejects absolute
/// paths, `..`, and empty segments so a malformed manifest can never
/// escape its transport root.
fn rel_path(root: &Path, rel: &str) -> Result<PathBuf, String> {
    let mut out = root.to_path_buf();
    if rel.is_empty() {
        return Ok(out);
    }
    for seg in rel.split('/') {
        if seg.is_empty() || seg == "." || seg == ".." || seg.contains('\\') {
            return Err(format!("invalid transport path {rel:?}"));
        }
        out.push(seg);
    }
    Ok(out)
}

/// Map io NotFound to `None`, everything else to a clean error.
fn absent_to_none<T>(r: std::io::Result<T>, what: &Path) -> Result<Option<T>, String> {
    match r {
        Ok(v) => Ok(Some(v)),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
        Err(e) => Err(format!("transport io on {}: {e}", what.display())),
    }
}

/// Byte length of the newline-terminated prefix of a checkpoint buffer —
/// the only part of `results.jsonl` a transport is allowed to publish.
fn newline_prefix(bytes: &[u8]) -> usize {
    bytes.iter().rposition(|&b| b == b'\n').map_or(0, |p| p + 1)
}

/// Atomically materialize `bytes` at `dest` on the *local* filesystem
/// (tmp + rename in the destination directory) — used for everything the
/// pull engines install where another process may be reading or folding.
fn install_atomic(dest: &Path, bytes: &[u8]) -> Result<(), String> {
    if let Some(parent) = dest.parent() {
        std::fs::create_dir_all(parent)
            .map_err(|e| format!("creating {}: {e}", parent.display()))?;
    }
    let mut name = dest
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    name.push_str(".install-tmp");
    let tmp = dest.with_file_name(name);
    std::fs::write(&tmp, bytes).map_err(|e| format!("writing {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, dest).map_err(|e| format!("installing {}: {e}", dest.display()))
}

/// Relative segment files referenced by a segmented (v4) skill-store
/// manifest, in manifest order. Flat stores — and bytes that are not a
/// manifest at all — reference no segments, so v3-era roots and plain
/// run-dir folds keep moving as exactly one file.
fn segment_files(bytes: &[u8]) -> Vec<String> {
    std::str::from_utf8(bytes)
        .ok()
        .and_then(|text| Json::parse(text).ok())
        .and_then(|j| {
            j.get("segments").and_then(|s| s.as_arr()).map(|segs| {
                segs.iter()
                    .filter_map(|seg| {
                        seg.get("file").and_then(|f| f.as_str()).map(str::to_string)
                    })
                    .collect()
            })
        })
        .unwrap_or_default()
}

// ------------------------------------------------------------------------
// The transport abstraction
// ------------------------------------------------------------------------

/// One worker's channel for moving run-dir artifacts between machines.
///
/// All paths are `/`-separated *relative* paths under the transport root.
/// Every method is callable from a single thread at a time per endpoint;
/// concurrent endpoints (the worker's pushes vs. the coordinator's pulls)
/// are safe because visibility is atomic (see the module docs).
pub trait RunDirTransport {
    /// Human-readable endpoint description for logs and errors.
    fn describe(&self) -> String;

    /// Cheap liveness probe: the transport root still exists and is
    /// reachable. Sync loops call it every cycle so a root that disappears
    /// mid-run becomes a clean, immediate error instead of a silent stall.
    fn check(&self) -> Result<(), String>;

    /// Byte length of a published file; `None` when absent.
    fn len(&self, rel: &str) -> Result<Option<u64>, String>;

    /// Full contents of a published file; `None` when absent.
    fn fetch(&self, rel: &str) -> Result<Option<Vec<u8>>, String>;

    /// Contents of a published file from byte `offset` to its end (the
    /// tail-sync primitive); `None` when absent, empty when `offset` is at
    /// or past the end.
    fn fetch_from(&self, rel: &str, offset: u64) -> Result<Option<Vec<u8>>, String>;

    /// Atomically publish `bytes` at `rel`, creating parents as needed. A
    /// reader observes either the previous contents or all of `bytes` —
    /// never a partial transfer.
    fn publish(&self, rel: &str, bytes: &[u8]) -> Result<(), String>;

    /// Atomically publish `bytes` at `rel` **only if nothing is published
    /// there yet**: of any number of racing callers (across processes and
    /// machines sharing the root), exactly one returns `Ok(true)` and the
    /// rest `Ok(false)` with the winner's bytes untouched. This is the
    /// claim primitive elastic lease scheduling is built on.
    fn publish_excl(&self, rel: &str, bytes: &[u8]) -> Result<bool, String>;

    /// Sorted names of the files directly under `rel` (staging and other
    /// dot-entries excluded); empty when the directory is absent.
    fn list(&self, rel: &str) -> Result<Vec<String>, String>;

    /// Sorted names of the subdirectories directly under `rel` (dot-entries
    /// excluded); empty when the directory is absent.
    fn list_dirs(&self, rel: &str) -> Result<Vec<String>, String>;

    /// For transports backed by a locally reachable directory: the absolute
    /// path `rel` maps to. `Some` enables the zero-copy path — workers run
    /// their shards directly inside the root and the coordinator tails it
    /// in place, skipping the push/pull copies entirely.
    fn local_dir(&self, _rel: &str) -> Option<PathBuf> {
        None
    }
}

/// Shared filesystem core behind both built-in transports.
#[derive(Debug, Clone)]
struct FsCore {
    root: PathBuf,
}

static PUBLISH_SEQ: AtomicU64 = AtomicU64::new(0);

impl FsCore {
    fn new(root: &Path) -> Result<FsCore, String> {
        std::fs::create_dir_all(root)
            .map_err(|e| format!("creating transport root {}: {e}", root.display()))?;
        Ok(FsCore {
            root: root.to_path_buf(),
        })
    }

    fn check(&self) -> Result<(), String> {
        if self.root.is_dir() {
            Ok(())
        } else {
            Err(format!(
                "transport root {} disappeared mid-run",
                self.root.display()
            ))
        }
    }

    fn len(&self, rel: &str) -> Result<Option<u64>, String> {
        let path = rel_path(&self.root, rel)?;
        Ok(absent_to_none(std::fs::metadata(&path), &path)?.map(|m| m.len()))
    }

    fn fetch(&self, rel: &str) -> Result<Option<Vec<u8>>, String> {
        let path = rel_path(&self.root, rel)?;
        absent_to_none(std::fs::read(&path), &path)
    }

    fn fetch_from(&self, rel: &str, offset: u64) -> Result<Option<Vec<u8>>, String> {
        let path = rel_path(&self.root, rel)?;
        let Some(mut f) = absent_to_none(std::fs::File::open(&path), &path)? else {
            return Ok(None);
        };
        let len = f
            .metadata()
            .map_err(|e| format!("transport io on {}: {e}", path.display()))?
            .len();
        if offset >= len {
            return Ok(Some(Vec::new()));
        }
        f.seek(SeekFrom::Start(offset))
            .map_err(|e| format!("transport io on {}: {e}", path.display()))?;
        let mut buf = Vec::with_capacity((len - offset) as usize);
        f.read_to_end(&mut buf)
            .map_err(|e| format!("transport io on {}: {e}", path.display()))?;
        Ok(Some(buf))
    }

    /// Staged atomic publish. `fault` simulates a mid-file transfer
    /// interruption for the determinism batteries (see [`MirrorDir`]).
    fn publish(
        &self,
        rel: &str,
        bytes: &[u8],
        fault: Option<&TransferFault>,
    ) -> Result<(), String> {
        let target = rel_path(&self.root, rel)?;
        if let Some(parent) = target.parent() {
            std::fs::create_dir_all(parent)
                .map_err(|e| format!("creating {}: {e}", parent.display()))?;
        }
        let staging_dir = self.root.join(".staging");
        std::fs::create_dir_all(&staging_dir)
            .map_err(|e| format!("creating {}: {e}", staging_dir.display()))?;
        let seq = PUBLISH_SEQ.fetch_add(1, Ordering::Relaxed);
        let tmp = staging_dir.join(format!("pub-{}-{seq}", std::process::id()));
        if let Some(f) = fault {
            if let Some(msg) = f.fire(rel, &tmp, bytes) {
                return Err(msg);
            }
        }
        std::fs::write(&tmp, bytes).map_err(|e| format!("staging {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &target)
            .map_err(|e| format!("publishing {}: {e}", target.display()))
    }

    /// First-publish-wins. `rename` would silently replace an existing
    /// file, so the staged bytes are `hard_link`ed into place instead —
    /// link creation fails with `AlreadyExists` when the target is taken,
    /// which is exactly the atomic lose-the-race signal a claim needs.
    fn publish_excl(&self, rel: &str, bytes: &[u8]) -> Result<bool, String> {
        let target = rel_path(&self.root, rel)?;
        if let Some(parent) = target.parent() {
            std::fs::create_dir_all(parent)
                .map_err(|e| format!("creating {}: {e}", parent.display()))?;
        }
        let staging_dir = self.root.join(".staging");
        std::fs::create_dir_all(&staging_dir)
            .map_err(|e| format!("creating {}: {e}", staging_dir.display()))?;
        let seq = PUBLISH_SEQ.fetch_add(1, Ordering::Relaxed);
        let tmp = staging_dir.join(format!("excl-{}-{seq}", std::process::id()));
        std::fs::write(&tmp, bytes).map_err(|e| format!("staging {}: {e}", tmp.display()))?;
        let won = match std::fs::hard_link(&tmp, &target) {
            Ok(()) => true,
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => false,
            Err(e) => {
                let _ = std::fs::remove_file(&tmp);
                return Err(format!("claiming {}: {e}", target.display()));
            }
        };
        let _ = std::fs::remove_file(&tmp);
        Ok(won)
    }

    fn list_entries(&self, rel: &str, dirs: bool) -> Result<Vec<String>, String> {
        let path = rel_path(&self.root, rel)?;
        let Some(rd) = absent_to_none(std::fs::read_dir(&path), &path)? else {
            return Ok(Vec::new());
        };
        let mut out = Vec::new();
        for entry in rd {
            let entry = entry.map_err(|e| format!("transport io on {}: {e}", path.display()))?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.starts_with('.') {
                continue;
            }
            let is_dir = entry
                .file_type()
                .map_err(|e| format!("transport io on {}: {e}", path.display()))?
                .is_dir();
            if is_dir == dirs {
                out.push(name);
            }
        }
        out.sort();
        Ok(out)
    }
}

/// Test hook configuration: the first [`MirrorDir`] publish whose relative
/// path contains `substr` writes *half* its bytes to the staging file and
/// fails — the exact footprint of a transfer cut off mid-file — once per
/// `marker` file, so the retry on the next sync cycle succeeds and the
/// batteries can assert byte-identical output through the interruption.
///
/// Armed from `KS_TEST_TRANSPORT_FAIL_SUBSTR` /
/// `KS_TEST_TRANSPORT_FAIL_MARKER` *once, at transport construction* (the
/// CLI/CI path sets them on the spawned worker process), or directly via
/// [`MirrorDir::with_fault_hook`] (the in-process test path — no
/// process-global env mutation, which would race other threads' getenv).
#[derive(Debug, Clone)]
struct TransferFault {
    substr: String,
    marker: PathBuf,
}

impl TransferFault {
    fn from_env() -> Option<TransferFault> {
        let substr = std::env::var("KS_TEST_TRANSPORT_FAIL_SUBSTR").ok()?;
        let marker = std::env::var("KS_TEST_TRANSPORT_FAIL_MARKER").ok()?;
        if substr.is_empty() || marker.is_empty() {
            return None;
        }
        Some(TransferFault {
            substr,
            marker: PathBuf::from(marker),
        })
    }

    fn fire(&self, rel: &str, staging: &Path, bytes: &[u8]) -> Option<String> {
        if !rel.contains(&self.substr) || self.marker.exists() {
            return None;
        }
        let _ = std::fs::write(&self.marker, "interrupted\n");
        let _ = std::fs::write(staging, &bytes[..bytes.len() / 2]);
        Some(format!(
            "KS_TEST_TRANSPORT_FAIL_SUBSTR: simulated mid-file interruption publishing {rel}"
        ))
    }
}

/// Shared-filesystem transport: the root is a directory every party can
/// already reach (NFS, a bind mount, one machine). Zero-copy: it exposes
/// its paths via [`RunDirTransport::local_dir`], so workers stream their
/// run dirs directly into the root and the coordinator tail-follows them
/// in place — exactly the single-machine launcher dataflow.
#[derive(Debug, Clone)]
pub struct LocalFs {
    core: FsCore,
}

impl LocalFs {
    /// Open (creating if needed) a shared-directory transport at `root`.
    pub fn new(root: &Path) -> Result<LocalFs, String> {
        Ok(LocalFs {
            core: FsCore::new(root)?,
        })
    }
}

impl RunDirTransport for LocalFs {
    fn describe(&self) -> String {
        format!("local-fs {}", self.core.root.display())
    }
    fn check(&self) -> Result<(), String> {
        self.core.check()
    }
    fn len(&self, rel: &str) -> Result<Option<u64>, String> {
        self.core.len(rel)
    }
    fn fetch(&self, rel: &str) -> Result<Option<Vec<u8>>, String> {
        self.core.fetch(rel)
    }
    fn fetch_from(&self, rel: &str, offset: u64) -> Result<Option<Vec<u8>>, String> {
        self.core.fetch_from(rel, offset)
    }
    fn publish(&self, rel: &str, bytes: &[u8]) -> Result<(), String> {
        self.core.publish(rel, bytes, None)
    }
    fn publish_excl(&self, rel: &str, bytes: &[u8]) -> Result<bool, String> {
        self.core.publish_excl(rel, bytes)
    }
    fn list(&self, rel: &str) -> Result<Vec<String>, String> {
        self.core.list_entries(rel, false)
    }
    fn list_dirs(&self, rel: &str) -> Result<Vec<String>, String> {
        self.core.list_entries(rel, true)
    }
    fn local_dir(&self, rel: &str) -> Option<PathBuf> {
        rel_path(&self.core.root, rel).ok()
    }
}

/// Object-store-shaped transport: a directory that is only ever accessed
/// through `list`/`fetch`/`publish` with staged atomic writes — the CI
/// stand-in for S3/GCS/rsync-over-ssh. It deliberately does *not* expose
/// local paths, so every byte moves through the same push/pull engines a
/// networked backend would use, and its publish path carries the
/// interrupted-transfer test hook.
#[derive(Debug, Clone)]
pub struct MirrorDir {
    core: FsCore,
    fault: Option<TransferFault>,
}

impl MirrorDir {
    /// Open (creating if needed) an object-store-shaped transport at
    /// `root`. The interrupted-transfer test hook is armed from the
    /// `KS_TEST_TRANSPORT_FAIL_*` environment (read once, here) when the
    /// spawning process set it.
    pub fn new(root: &Path) -> Result<MirrorDir, String> {
        Ok(MirrorDir {
            core: FsCore::new(root)?,
            fault: TransferFault::from_env(),
        })
    }

    /// Test-only: arm the interrupted-transfer hook directly — the first
    /// publish whose relative path contains `substr` is cut off mid-file
    /// (half the bytes reach staging, the call errors), once per `marker`
    /// file — without touching the process environment, where an
    /// in-process `set_var` would race other threads' `getenv` under the
    /// parallel test harness.
    pub fn with_fault_hook(mut self, substr: &str, marker: &Path) -> MirrorDir {
        self.fault = Some(TransferFault {
            substr: substr.to_string(),
            marker: marker.to_path_buf(),
        });
        self
    }
}

impl RunDirTransport for MirrorDir {
    fn describe(&self) -> String {
        format!("mirror-dir {}", self.core.root.display())
    }
    fn check(&self) -> Result<(), String> {
        self.core.check()
    }
    fn len(&self, rel: &str) -> Result<Option<u64>, String> {
        self.core.len(rel)
    }
    fn fetch(&self, rel: &str) -> Result<Option<Vec<u8>>, String> {
        self.core.fetch(rel)
    }
    fn fetch_from(&self, rel: &str, offset: u64) -> Result<Option<Vec<u8>>, String> {
        self.core.fetch_from(rel, offset)
    }
    fn publish(&self, rel: &str, bytes: &[u8]) -> Result<(), String> {
        self.core.publish(rel, bytes, self.fault.as_ref())
    }
    fn publish_excl(&self, rel: &str, bytes: &[u8]) -> Result<bool, String> {
        self.core.publish_excl(rel, bytes)
    }
    fn list(&self, rel: &str) -> Result<Vec<String>, String> {
        self.core.list_entries(rel, false)
    }
    fn list_dirs(&self, rel: &str) -> Result<Vec<String>, String> {
        self.core.list_entries(rel, true)
    }
}

// ------------------------------------------------------------------------
// Worker manifest
// ------------------------------------------------------------------------

/// Which transport implementation a worker uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// Shared filesystem (zero-copy); manifest kind `"local-fs"`.
    LocalFs,
    /// Object-store-shaped staging directory; manifest kind `"mirror-dir"`.
    MirrorDir,
}

impl TransportKind {
    fn parse(s: &str) -> Result<TransportKind, String> {
        match s {
            "local-fs" => Ok(TransportKind::LocalFs),
            "mirror-dir" => Ok(TransportKind::MirrorDir),
            other => Err(format!(
                "unknown transport kind {other:?} (expected \"local-fs\" or \"mirror-dir\")"
            )),
        }
    }
}

/// One worker's transport endpoint description.
#[derive(Debug, Clone)]
pub struct TransportSpec {
    /// Which implementation to build.
    pub kind: TransportKind,
    /// The transport root (a shared path for `local-fs`, the store
    /// directory for `mirror-dir`).
    pub root: PathBuf,
}

impl TransportSpec {
    /// Build the transport, creating its root.
    pub fn build(&self) -> Result<Box<dyn RunDirTransport>, String> {
        Ok(match self.kind {
            TransportKind::LocalFs => Box::new(LocalFs::new(&self.root)?),
            TransportKind::MirrorDir => Box::new(MirrorDir::new(&self.root)?),
        })
    }
}

/// One row of the worker manifest: a worker id, the contiguous shard range
/// it runs, and the transport it publishes through.
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    /// Unique worker id (used in paths, logs, and crash markers).
    pub id: String,
    /// First global shard index this worker runs (inclusive).
    pub shard_lo: usize,
    /// Last global shard index this worker runs (inclusive).
    pub shard_hi: usize,
    /// The worker's transport endpoint.
    pub transport: TransportSpec,
    /// Device preset this worker's shards run against (heterogeneous
    /// fleet). `None` = whatever the launch-wide passthrough (or the
    /// default) says. When set, the launcher appends `--device <name>` to
    /// this worker's child invocations; its evidence lands in that preset's
    /// skill-store partition and the final merge records the joined preset
    /// set. Validated against the built-in presets at parse time.
    pub device: Option<String>,
}

impl WorkerSpec {
    /// Does this worker run global shard `index`?
    pub fn owns(&self, index: usize) -> bool {
        (self.shard_lo..=self.shard_hi).contains(&index)
    }

    /// The global shard indices this worker runs.
    pub fn shard_indices(&self) -> std::ops::RangeInclusive<usize> {
        self.shard_lo..=self.shard_hi
    }
}

/// The fleet description `launch --manifest <file>` and `worker` read, in
/// one of two shapes. **Static**: a total shard count plus one
/// [`WorkerSpec`] per machine with a contiguous shard range; parsing
/// validates the whole document — the ranges must be an exact, disjoint
/// cover of `0..total_shards` and the ids unique — so a bad manifest is a
/// clean error before any process spawns. **Elastic**: a total *batch*
/// count plus a fleet-shared lease transport; workers carry no ranges and
/// claim batches dynamically through [`Lease`] files.
#[derive(Debug, Clone)]
pub struct WorkerManifest {
    /// Static mode: total number of shards the matrix is split into,
    /// fleet-wide. Zero in elastic mode.
    pub total_shards: usize,
    /// Elastic mode: number of contiguous cell batches the matrix is cut
    /// into for lease claiming. Zero in static mode.
    pub total_batches: usize,
    /// Elastic mode: the lease transport every machine (workers and the
    /// coordinator) shares — where claims, heartbeats, and re-dispatch
    /// markers live. `None` in static mode.
    pub lease: Option<TransportSpec>,
    /// The workers, in file order.
    pub workers: Vec<WorkerSpec>,
}

impl WorkerManifest {
    /// Parse and validate a manifest document. The static format:
    ///
    /// ```json
    /// {"version": 1, "total_shards": 2, "workers": [
    ///   {"id": "w0", "shard_lo": 0, "shard_hi": 0,
    ///    "transport": {"kind": "mirror-dir", "root": "/srv/ks/w0"}},
    ///   {"id": "w1", "shard_lo": 1, "shard_hi": 1, "device": "tpu-like",
    ///    "transport": {"kind": "local-fs", "root": "/mnt/shared/w1"}}
    /// ]}
    /// ```
    ///
    /// Any row (static or elastic) may carry an optional `"device"` preset
    /// name — a heterogeneous fleet; the launcher forwards it to that
    /// worker's children as `--device`.
    ///
    /// and the elastic format (no ranges anywhere; `lease` is the shared
    /// claim root):
    ///
    /// ```json
    /// {"version": 1, "total_batches": 6,
    ///  "lease": {"kind": "mirror-dir", "root": "/srv/ks/leases"},
    ///  "workers": [
    ///   {"id": "w0", "transport": {"kind": "mirror-dir", "root": "/srv/ks/w0"}},
    ///   {"id": "w1", "transport": {"kind": "mirror-dir", "root": "/srv/ks/w1"}}
    /// ]}
    /// ```
    pub fn parse(text: &str) -> Result<WorkerManifest, String> {
        let j = Json::parse(text).map_err(|e| format!("worker manifest: {e}"))?;
        if let Some(v) = j.get("version").and_then(|v| v.as_f64()) {
            if v != 1.0 {
                return Err(format!("worker manifest: unsupported version {v}"));
            }
        }
        let parse_transport = |t: &Json, what: &str| -> Result<TransportSpec, String> {
            let kind = TransportKind::parse(
                t.get("kind")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| format!("worker manifest: {what}: missing kind"))?,
            )
            .map_err(|e| format!("worker manifest: {what}: {e}"))?;
            let root = t
                .get("root")
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("worker manifest: {what}: missing root"))?;
            if root.is_empty() {
                return Err(format!("worker manifest: {what}: empty root"));
            }
            Ok(TransportSpec {
                kind,
                root: PathBuf::from(root),
            })
        };
        let lease = j
            .get("lease")
            .map(|t| parse_transport(t, "lease transport"))
            .transpose()?;
        let elastic = lease.is_some();
        let total_batches = j.get("total_batches").and_then(|v| v.as_usize());
        let total_shards = j.get("total_shards").and_then(|v| v.as_usize());
        if elastic && total_shards.is_some() {
            return Err(
                "worker manifest: an elastic manifest (with a lease transport) takes \
                 total_batches, not total_shards"
                    .to_string(),
            );
        }
        if !elastic && total_batches.is_some() {
            return Err(
                "worker manifest: total_batches requires a lease transport (elastic mode)"
                    .to_string(),
            );
        }
        let workers_json = j
            .get("workers")
            .and_then(|v| v.as_arr())
            .ok_or("worker manifest: missing workers array")?;
        let mut workers = Vec::new();
        for (i, w) in workers_json.iter().enumerate() {
            let at = |what: &str| format!("worker manifest entry {i}: missing {what}");
            let id = w
                .get("id")
                .and_then(|v| v.as_str())
                .ok_or_else(|| at("id"))?
                .to_string();
            let (shard_lo, shard_hi) = if elastic {
                if w.get("shard_lo").is_some() || w.get("shard_hi").is_some() {
                    return Err(format!(
                        "worker manifest entry {i} ({id}): elastic workers claim batches \
                         through leases and must not declare shard ranges"
                    ));
                }
                (0, 0)
            } else {
                (
                    w.get("shard_lo")
                        .and_then(|v| v.as_usize())
                        .ok_or_else(|| at("shard_lo"))?,
                    w.get("shard_hi")
                        .and_then(|v| v.as_usize())
                        .ok_or_else(|| at("shard_hi"))?,
                )
            };
            let t = w.get("transport").ok_or_else(|| at("transport"))?;
            let transport = parse_transport(t, &format!("entry {i} transport"))?;
            if id.is_empty() {
                return Err(format!("worker manifest entry {i}: empty id"));
            }
            let device = match w.get("device") {
                None => None,
                Some(v) => {
                    let name = v.as_str().ok_or_else(|| {
                        format!("worker manifest entry {i} ({id}): device must be a string")
                    })?;
                    if crate::device::machine::DeviceSpec::by_name(name).is_none() {
                        let known: Vec<&str> = crate::device::machine::DeviceSpec::presets()
                            .iter()
                            .map(|d| d.name)
                            .collect();
                        return Err(format!(
                            "worker manifest entry {i} ({id}): unknown device preset \
                             {name:?} (known presets: {})",
                            known.join(", ")
                        ));
                    }
                    Some(name.to_string())
                }
            };
            workers.push(WorkerSpec {
                id,
                shard_lo,
                shard_hi,
                transport,
                device,
            });
        }
        let m = WorkerManifest {
            total_shards: total_shards.unwrap_or(0),
            total_batches: total_batches.unwrap_or(0),
            lease,
            workers,
        };
        m.validate()?;
        Ok(m)
    }

    /// Read and parse a manifest file.
    pub fn load(path: &Path) -> Result<WorkerManifest, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading worker manifest {}: {e}", path.display()))?;
        WorkerManifest::parse(&text)
    }

    /// Elastic manifests carry a shared lease transport and a batch count
    /// instead of per-worker shard ranges.
    pub fn is_elastic(&self) -> bool {
        self.lease.is_some()
    }

    /// The structural rules: at least one worker, unique non-empty ids,
    /// and — in static mode — well-formed ranges with shard coverage that
    /// is exact (no gaps) and disjoint (no overlaps); in elastic mode a
    /// batch count of at least one (coverage is dynamic by construction).
    pub fn validate(&self) -> Result<(), String> {
        if self.is_elastic() {
            if self.total_batches == 0 {
                return Err("worker manifest: total_batches must be >= 1".to_string());
            }
        } else if self.total_shards == 0 {
            return Err("worker manifest: total_shards must be >= 1".to_string());
        }
        if self.workers.is_empty() {
            return Err("worker manifest: needs at least one worker".to_string());
        }
        let mut seen_ids: BTreeSet<&str> = BTreeSet::new();
        for w in &self.workers {
            if !seen_ids.insert(&w.id) {
                return Err(format!("worker manifest: duplicate worker id {:?}", w.id));
            }
        }
        if self.is_elastic() {
            return Ok(());
        }
        let mut owners: Vec<Vec<&str>> = vec![Vec::new(); self.total_shards];
        for w in &self.workers {
            if w.shard_lo > w.shard_hi {
                return Err(format!(
                    "worker manifest: worker {:?} has shard_lo {} > shard_hi {}",
                    w.id, w.shard_lo, w.shard_hi
                ));
            }
            if w.shard_hi >= self.total_shards {
                return Err(format!(
                    "worker manifest: worker {:?} claims shard {} but total_shards is {}",
                    w.id, w.shard_hi, self.total_shards
                ));
            }
            for i in w.shard_indices() {
                owners[i].push(&w.id);
            }
        }
        let overlapping: Vec<String> = owners
            .iter()
            .enumerate()
            .filter(|(_, o)| o.len() > 1)
            .map(|(i, o)| format!("shard {i} claimed by {o:?}"))
            .collect();
        if !overlapping.is_empty() {
            return Err(format!(
                "worker manifest: overlapping shard ranges ({})",
                overlapping.join("; ")
            ));
        }
        let gaps: Vec<usize> = owners
            .iter()
            .enumerate()
            .filter(|(_, o)| o.is_empty())
            .map(|(i, _)| i)
            .collect();
        if !gaps.is_empty() {
            return Err(format!(
                "worker manifest: shard index(es) {gaps:?} are covered by no worker \
                 (ranges must exactly cover 0..{})",
                self.total_shards
            ));
        }
        Ok(())
    }

    /// Look up one worker by id.
    pub fn worker(&self, id: &str) -> Option<&WorkerSpec> {
        self.workers.iter().find(|w| w.id == id)
    }

    /// All worker ids, in file order (for error messages).
    pub fn worker_ids(&self) -> Vec<&str> {
        self.workers.iter().map(|w| w.id.as_str()).collect()
    }
}

// ------------------------------------------------------------------------
// Elastic lease scheduling: claims, heartbeats, expiry, re-dispatch
// ------------------------------------------------------------------------

/// Relative directory on the lease transport holding claims, heartbeats,
/// and re-dispatch markers.
pub const LEASES: &str = "leases";

/// Name of the lease file for attempt `attempt` at batch `batch`
/// (`batch-<k>.attempt-<a>.json`). One file per *attempt*, not per worker:
/// claim exclusivity is the file system's first-link-wins on this exact
/// name, and the attempt history doubles as the re-dispatch audit trail
/// (the holder's id lives in the lease body).
pub fn lease_name(batch: usize, attempt: usize) -> String {
    format!("batch-{batch}.attempt-{attempt}.json")
}

/// Name of the coordinator's re-dispatch marker for one attempt: once
/// published, the attempt is dead to the fleet and the batch is claimable
/// at the next attempt number.
pub fn lease_expired_name(batch: usize, attempt: usize) -> String {
    format!("batch-{batch}.attempt-{attempt}.expired")
}

/// `(batch, attempt, is_expired_marker)` encoded in a lease-directory file
/// name, if it is one.
pub fn parse_lease_name(name: &str) -> Option<(usize, usize, bool)> {
    let rest = name.strip_prefix("batch-")?;
    let (batch, rest) = rest.split_once(".attempt-")?;
    let (attempt, expired) = match rest.strip_suffix(".json") {
        Some(a) => (a, false),
        None => (rest.strip_suffix(".expired")?, true),
    };
    Some((batch.parse().ok()?, attempt.parse().ok()?, expired))
}

/// One attempt's claim-plus-heartbeat record, stored as the lease file's
/// body. The holder republishes it (plain overwrite — it owns the claim)
/// whenever `progress` advances, and once more with `done` after its whole
/// batch (including the `complete` marker) is pushed.
///
/// `progress` is a *counter* — the newline-terminated byte length of the
/// holder's local checkpoint — never a wall-clock timestamp: the
/// coordinator declares an attempt dead when the counter stops advancing
/// across its own expiry budget, so clock skew between machines (which
/// made mtime-based liveness judgments wrong by construction) cannot
/// expire a healthy straggler or keep a dead one alive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Lease {
    /// The claimed batch index.
    pub batch: usize,
    /// Attempt number at this batch (0 = first claim, +1 per re-dispatch).
    pub attempt: usize,
    /// Id of the worker holding the attempt.
    pub worker: String,
    /// Newline-terminated byte length of the holder's local checkpoint for
    /// this batch — the liveness counter.
    pub progress: u64,
    /// The holder finished the batch and published its `complete` marker.
    pub done: bool,
}

impl Lease {
    /// Transport-relative path of this attempt's lease file.
    pub fn rel(&self) -> String {
        format!("{LEASES}/{}", lease_name(self.batch, self.attempt))
    }

    /// Serialize to the lease file body.
    pub fn to_bytes(&self) -> Vec<u8> {
        use crate::util::json as uj;
        format!(
            "{}\n",
            uj::obj(vec![
                ("version", uj::num(1.0)),
                ("batch", uj::num(self.batch as f64)),
                ("attempt", uj::num(self.attempt as f64)),
                ("worker", uj::s(&self.worker)),
                ("progress", uj::s(&self.progress.to_string())),
                ("done", Json::Bool(self.done)),
            ])
        )
        .into_bytes()
    }

    /// Parse a lease file body. Publishes are atomic, so a body that does
    /// not parse is foreign junk in the lease root — a loud error, never
    /// a silently ignored claim.
    pub fn parse(bytes: &[u8]) -> Result<Lease, String> {
        let text = std::str::from_utf8(bytes).map_err(|e| format!("lease not utf-8: {e}"))?;
        let j = Json::parse(text).map_err(|e| format!("lease does not parse: {e}"))?;
        let get_n = |k: &str| {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| format!("lease missing {k}"))
        };
        let progress = match j.get("progress") {
            Some(Json::Str(s)) => s
                .parse::<u64>()
                .map_err(|e| format!("lease bad progress: {e}"))?,
            Some(Json::Num(n)) => *n as u64,
            _ => return Err("lease missing progress".to_string()),
        };
        Ok(Lease {
            batch: get_n("batch")?,
            attempt: get_n("attempt")?,
            worker: j
                .get("worker")
                .and_then(|v| v.as_str())
                .ok_or("lease missing worker")?
                .to_string(),
            progress,
            done: matches!(j.get("done"), Some(Json::Bool(true))),
        })
    }
}

/// One batch's aggregated lease state, as read off the lease transport.
#[derive(Debug, Clone)]
pub struct BatchLeaseState {
    /// The batch index.
    pub batch: usize,
    /// Number of attempt files observed (attempt numbers are contiguous
    /// from 0, so this is also the next attempt number).
    pub attempts: usize,
    /// Parsed body of the latest attempt's lease, when one exists.
    pub latest: Option<Lease>,
    /// The latest attempt carries the coordinator's re-dispatch marker.
    pub latest_expired: bool,
    /// Some attempt (not necessarily the latest — a straggler may finish
    /// *after* being expired and re-dispatched) reported `done`.
    pub done: bool,
}

impl BatchLeaseState {
    /// A worker may claim this batch now: never claimed, or the latest
    /// attempt was expired by the coordinator — and nobody finished it yet.
    pub fn claimable(&self) -> bool {
        !self.done && (self.attempts == 0 || self.latest_expired)
    }
}

/// Read the whole lease board for `total_batches` batches off the lease
/// transport. Every attempt's body is fetched and parsed, so `done` is
/// exact even when a re-dispatched straggler finished late.
pub fn read_lease_board(
    transport: &dyn RunDirTransport,
    total_batches: usize,
) -> Result<Vec<BatchLeaseState>, String> {
    let mut attempts: BTreeMap<usize, usize> = BTreeMap::new(); // batch -> max attempt
    let mut expired: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut names: Vec<(usize, usize)> = Vec::new();
    for name in transport.list(LEASES)? {
        let Some((batch, attempt, is_expired)) = parse_lease_name(&name) else {
            continue;
        };
        if batch >= total_batches {
            return Err(format!(
                "lease root {} holds a lease for batch {batch} but the manifest declares \
                 only {total_batches} batch(es) — it belongs to a different run; refusing \
                 to schedule over it",
                transport.describe()
            ));
        }
        if is_expired {
            expired.insert((batch, attempt));
        } else {
            let slot = attempts.entry(batch).or_insert(0);
            *slot = (*slot).max(attempt + 1);
            names.push((batch, attempt));
        }
    }
    let mut done: BTreeSet<usize> = BTreeSet::new();
    let mut latest: BTreeMap<usize, Lease> = BTreeMap::new();
    for (batch, attempt) in names {
        let rel = format!("{LEASES}/{}", lease_name(batch, attempt));
        let Some(bytes) = transport.fetch(&rel)? else {
            // Listed a moment ago; a lease file is never deleted, so this
            // is a vanished-root class failure surfaced by check() soon.
            continue;
        };
        let lease = Lease::parse(&bytes).map_err(|e| format!("lease {rel}: {e}"))?;
        if lease.done {
            done.insert(batch);
        }
        if attempt + 1 == attempts.get(&batch).copied().unwrap_or(0) {
            latest.insert(batch, lease);
        }
    }
    Ok((0..total_batches)
        .map(|batch| {
            let n = attempts.get(&batch).copied().unwrap_or(0);
            BatchLeaseState {
                batch,
                attempts: n,
                latest: latest.get(&batch).cloned(),
                latest_expired: n > 0 && expired.contains(&(batch, n - 1)),
                done: done.contains(&batch),
            }
        })
        .collect())
}

/// Try to claim the lowest claimable batch on the board for `worker`.
/// Returns the won lease, or `None` when nothing is claimable right now
/// (all batches held or done) or every race was lost this round (the
/// caller re-reads the board and tries again).
pub fn claim_next_batch(
    transport: &dyn RunDirTransport,
    board: &[BatchLeaseState],
    worker: &str,
) -> Result<Option<Lease>, String> {
    for state in board.iter().filter(|s| s.claimable()) {
        let lease = Lease {
            batch: state.batch,
            attempt: state.attempts,
            worker: worker.to_string(),
            progress: 0,
            done: false,
        };
        if transport.publish_excl(&lease.rel(), &lease.to_bytes())? {
            return Ok(Some(lease));
        }
    }
    Ok(None)
}

/// Publish the coordinator's re-dispatch marker for one attempt
/// (idempotent — first publish wins and the marker body is constant).
pub fn expire_lease(
    transport: &dyn RunDirTransport,
    batch: usize,
    attempt: usize,
) -> Result<bool, String> {
    transport.publish_excl(
        &format!("{LEASES}/{}", lease_expired_name(batch, attempt)),
        b"expired\n",
    )
}

// ------------------------------------------------------------------------
// Worker-side sync engines (push own artifacts up, pull peers' deltas down)
// ------------------------------------------------------------------------

/// Contiguous covered length of the checkpoint segment tiling under `rel`
/// on a transport. Segments must tile from byte 0 with no gap or overlap;
/// anything else means the root was written by a different run (or a
/// transfer protocol this version does not speak) and is a loud error,
/// never a silent overwrite.
fn segment_cover(transport: &dyn RunDirTransport, rel: &str) -> Result<u64, String> {
    let mut segs: Vec<(u64, u64)> = Vec::new();
    for name in transport.list(rel)? {
        if let Some(start) = parse_segment_name(&name) {
            let len = transport
                .len(&format!("{rel}/{name}"))?
                .ok_or_else(|| format!("segment {rel}/{name} vanished while being listed"))?;
            segs.push((start, len));
        }
    }
    segs.sort_unstable();
    let mut covered = 0u64;
    for (start, len) in segs {
        if start != covered {
            return Err(format!(
                "checkpoint segments under {rel} on {} do not tile contiguously (next \
                 segment starts at byte {start}, covered so far {covered}) — the \
                 transport root belongs to a different run; refusing to publish over it",
                transport.describe()
            ));
        }
        covered += len;
    }
    Ok(covered)
}

/// Publishes one local shard (or elastic batch) run dir through a
/// transport, incrementally: the manifest once it exists, `results.jsonl`
/// growth as immutable newline-boundary segment files, `skills.json` and
/// warm-start snapshots whenever their bytes change, and the `complete`
/// marker strictly last.
#[derive(Debug)]
pub struct ShardPush {
    dir: PathBuf,
    rel: String,
    results_pushed: u64,
    /// Local checkpoint length at the last cycle that read it; the file is
    /// append-only, so an unchanged length means unchanged content and the
    /// (potentially large) re-read can be skipped. `None` = never read —
    /// the first cycle always reads, so the stale-root check always runs.
    results_seen_len: Option<u64>,
    manifest_pushed: bool,
    complete_pushed: bool,
    skills_last: Option<Vec<u8>>,
    /// Segment files already published. Segments are immutable and their
    /// names are never reused (the store's rotation counter only grows),
    /// so once published a segment never needs another byte-compare.
    segments_pushed: BTreeSet<String>,
    snapshots_last: BTreeMap<String, Vec<u8>>,
    /// Elastic batches only: tolerate a published cover ahead of the local
    /// checkpoint (a re-dispatched attempt recomputing identical bytes)
    /// instead of treating it as a stale root.
    catch_up: bool,
}

impl ShardPush {
    /// Start pushing local run dir `dir` as global shard `shard_index`.
    /// Picks up where a previous (crashed) worker process left off: the
    /// already-published checkpoint cover is read back off the transport's
    /// segment tiling, and a transport that holds *more* than the local
    /// checkpoint is a clean error (a stale or foreign root, never
    /// silently overwritten).
    pub fn new(
        dir: &Path,
        shard_index: usize,
        transport: &dyn RunDirTransport,
    ) -> Result<ShardPush, String> {
        ShardPush::with_rel(dir, up_shard_rel(shard_index), transport)
    }

    /// Start pushing local run dir `dir` as elastic batch `batch`. Unlike
    /// the static constructor, a transport that holds *more* checkpoint
    /// bytes than the local dir is not an error: a re-dispatched batch
    /// recomputes the same (deterministic) bytes from scratch, and the
    /// push simply waits for the local checkpoint to catch up to the cover
    /// a previous attempt already published.
    pub fn new_batch(
        dir: &Path,
        batch: usize,
        transport: &dyn RunDirTransport,
    ) -> Result<ShardPush, String> {
        let mut push = ShardPush::with_rel(dir, up_batch_rel(batch), transport)?;
        push.catch_up = true;
        Ok(push)
    }

    fn with_rel(dir: &Path, rel: String, transport: &dyn RunDirTransport) -> Result<ShardPush, String> {
        // A whole-file checkpoint on the transport was published by the
        // pre-segment protocol; mixing layouts would double-count bytes.
        if transport.len(&format!("{rel}/{RESULTS}"))?.is_some() {
            return Err(format!(
                "{} holds a whole-file {RESULTS} under {rel}, published by an older \
                 (pre-segment) version of this tool; refusing to mix checkpoint layouts",
                transport.describe()
            ));
        }
        let covered = segment_cover(transport, &rel)?;
        Ok(ShardPush {
            dir: dir.to_path_buf(),
            rel,
            results_pushed: covered,
            results_seen_len: None,
            manifest_pushed: false,
            complete_pushed: false,
            skills_last: None,
            segments_pushed: BTreeSet::new(),
            snapshots_last: BTreeMap::new(),
            catch_up: false,
        })
    }

    /// Newline-terminated bytes of the local checkpoint published so far —
    /// the monotone progress counter elastic lease heartbeats carry.
    pub fn results_pushed(&self) -> u64 {
        self.results_pushed
    }

    /// Every artifact (including `complete`) has been published.
    pub fn is_complete(&self) -> bool {
        self.complete_pushed
    }

    /// One push cycle; returns whether anything was published. Errors are
    /// retryable — state only advances after a successful publish, so the
    /// next cycle re-attempts exactly the failed transfer.
    pub fn cycle(&mut self, transport: &dyn RunDirTransport) -> Result<bool, String> {
        if self.complete_pushed {
            return Ok(false);
        }
        let mut progress = false;
        // Observe completion *before* reading anything: the producer writes
        // `complete` after its last byte, so files read after a positive
        // probe are final — and `complete` itself is published strictly
        // last, below.
        let local_complete = self.dir.join(RunDir::COMPLETE_MARKER).exists();

        if !self.manifest_pushed {
            let path = self.dir.join(MANIFEST);
            if path.exists() {
                let bytes = std::fs::read(&path)
                    .map_err(|e| format!("reading {}: {e}", path.display()))?;
                transport.publish(&format!("{}/{MANIFEST}", self.rel), &bytes)?;
                self.manifest_pushed = true;
                progress = true;
            }
        }

        let results = self.dir.join(RESULTS);
        if results.exists() {
            // Append-only file: an unchanged length means unchanged
            // content, so the (large, 10x/second) re-read is skipped. The
            // very first cycle always reads, so the stale-root check below
            // cannot be bypassed.
            let len = std::fs::metadata(&results)
                .map(|m| m.len())
                .map_err(|e| format!("reading {}: {e}", results.display()))?;
            if self.results_seen_len != Some(len) {
                let bytes = std::fs::read(&results)
                    .map_err(|e| format!("reading {}: {e}", results.display()))?;
                let prefix = newline_prefix(&bytes);
                if (prefix as u64) < self.results_pushed && (!self.catch_up || local_complete) {
                    // For a static shard this is a stale/foreign root. For
                    // an elastic batch mid-recompute it is the expected
                    // catch-up state — unless the batch claims to be
                    // *finished* while still short of the published cover,
                    // which can only mean the root holds someone else's
                    // bytes.
                    return Err(format!(
                        "{} already holds {} byte(s) but the local checkpoint has only {} \
                         newline-terminated byte(s) — the transport root belongs to a \
                         different (or newer) run; refusing to publish over it",
                        transport.describe(),
                        self.results_pushed,
                        prefix
                    ));
                }
                if (prefix as u64) > self.results_pushed {
                    // Only the new bytes travel: an immutable segment named
                    // by its start offset, so each growth step is O(delta)
                    // and the whole file is never re-pushed.
                    transport.publish(
                        &format!("{}/{}", self.rel, segment_name(self.results_pushed)),
                        &bytes[self.results_pushed as usize..prefix],
                    )?;
                    self.results_pushed = prefix as u64;
                    progress = true;
                }
                // Only remember the length once everything consumable from
                // it has been published, so a failed publish is retried.
                self.results_seen_len = Some(bytes.len() as u64);
            }
        } else if self.results_pushed > 0 {
            return Err(format!(
                "local checkpoint {} vanished after {} byte(s) were published",
                results.display(),
                self.results_pushed
            ));
        }

        // Stores and snapshots are small but rewritten rarely: read every
        // cycle and byte-compare against the last published content. No
        // (len, mtime) shortcut — two same-length writes landing within
        // the filesystem's timestamp granularity are indistinguishable to
        // an mtime probe, and a delta silently skipped mid-run corrupts
        // every peer folding it. The files are a few KB; correctness wins.
        let skills = self.dir.join(SKILLS);
        if skills.exists() {
            let bytes =
                std::fs::read(&skills).map_err(|e| format!("reading {}: {e}", skills.display()))?;
            if self.skills_last.as_deref() != Some(bytes.as_slice()) {
                // A segmented (v4) store is a directory: immutable segment
                // files plus the manifest that lists them. Segments travel
                // *before* the manifest so a puller that can read the
                // manifest can always resolve every file it references.
                for file in segment_files(&bytes) {
                    if self.segments_pushed.contains(&file) {
                        continue;
                    }
                    let path = rel_path(&self.dir, &file)?;
                    let seg = std::fs::read(&path)
                        .map_err(|e| format!("reading {}: {e}", path.display()))?;
                    transport.publish(&format!("{}/{file}", self.rel), &seg)?;
                    self.segments_pushed.insert(file);
                }
                transport.publish(&format!("{}/{SKILLS}", self.rel), &bytes)?;
                self.skills_last = Some(bytes);
                progress = true;
            }
        }

        for entry in std::fs::read_dir(&self.dir)
            .map_err(|e| format!("listing {}: {e}", self.dir.display()))?
        {
            let entry = entry.map_err(|e| format!("listing {}: {e}", self.dir.display()))?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if !(name.starts_with("memory_snapshot.") && name.ends_with(".json")) {
                continue;
            }
            let bytes = std::fs::read(entry.path())
                .map_err(|e| format!("reading {}: {e}", entry.path().display()))?;
            if self.snapshots_last.get(&name).map(|b| b.as_slice()) != Some(bytes.as_slice()) {
                transport.publish(&format!("{}/{name}", self.rel), &bytes)?;
                self.snapshots_last.insert(name, bytes);
                progress = true;
            }
        }

        if local_complete {
            transport.publish(
                &format!("{}/{}", self.rel, RunDir::COMPLETE_MARKER),
                b"complete\n",
            )?;
            self.complete_pushed = true;
            progress = true;
        }
        Ok(progress)
    }
}

/// Publishes a worker's *own* shards' exchange deltas from its local
/// exchange directory up through its transport. Deltas are immutable once
/// written (atomic save, deterministic content), so each file is pushed
/// exactly once per process lifetime — a restarted worker harmlessly
/// re-publishes identical bytes.
#[derive(Debug)]
pub struct ExchangePush {
    local: PathBuf,
    owned: Vec<usize>,
    pushed: BTreeSet<(String, String)>,
}

impl ExchangePush {
    /// Push deltas for the `owned` global shard indices from the local
    /// exchange directory `local`.
    pub fn new(local: &Path, owned: Vec<usize>) -> ExchangePush {
        ExchangePush {
            local: local.to_path_buf(),
            owned,
            pushed: BTreeSet::new(),
        }
    }

    /// One push cycle; returns whether anything was published.
    pub fn cycle(&mut self, transport: &dyn RunDirTransport) -> Result<bool, String> {
        if !self.local.exists() {
            return Ok(false);
        }
        let mut progress = false;
        for slug_entry in std::fs::read_dir(&self.local)
            .map_err(|e| format!("listing {}: {e}", self.local.display()))?
        {
            let slug_entry =
                slug_entry.map_err(|e| format!("listing {}: {e}", self.local.display()))?;
            if !slug_entry.path().is_dir() {
                continue;
            }
            let slug = slug_entry.file_name().to_string_lossy().into_owned();
            if slug.starts_with('.') {
                continue;
            }
            for entry in std::fs::read_dir(slug_entry.path())
                .map_err(|e| format!("listing {}: {e}", slug_entry.path().display()))?
            {
                let entry =
                    entry.map_err(|e| format!("listing {}: {e}", slug_entry.path().display()))?;
                let name = entry.file_name().to_string_lossy().into_owned();
                let Some((_, shard)) = parse_exchange_delta_name(&name) else {
                    continue;
                };
                if !self.owned.contains(&shard) {
                    // A peer's delta the pull engine installed locally —
                    // its owner publishes it; echoing it would be noise.
                    continue;
                }
                let key = (slug.clone(), name.clone());
                if self.pushed.contains(&key) {
                    continue;
                }
                let bytes = std::fs::read(entry.path())
                    .map_err(|e| format!("reading {}: {e}", entry.path().display()))?;
                transport.publish(&format!("{UP_EXCHANGE}/{slug}/{name}"), &bytes)?;
                self.pushed.insert(key);
                progress = true;
            }
        }
        Ok(progress)
    }
}

/// Installs the fleet's exchange deltas (re-published by the coordinator
/// into `down/exchange`) into a worker's local exchange directory, where
/// its shard processes wait for them at epoch boundaries. Every delta is
/// parsed before installation — a file that does not parse as a store is
/// skipped with a warning (once) rather than handed to a folding shard or
/// allowed to wedge the whole sync loop: publishes are atomic, so a
/// corrupt delta is foreign junk, not a half transfer, and if a shard
/// genuinely needed it the peer-wait timeout surfaces a pointed error.
#[derive(Debug)]
pub struct ExchangePull {
    local: PathBuf,
    skipped: BTreeSet<(String, String)>,
}

impl ExchangePull {
    /// Install pulled deltas into the local exchange directory `local`.
    pub fn new(local: &Path) -> ExchangePull {
        ExchangePull {
            local: local.to_path_buf(),
            skipped: BTreeSet::new(),
        }
    }

    /// One pull cycle; returns whether anything was installed.
    pub fn cycle(&mut self, transport: &dyn RunDirTransport) -> Result<bool, String> {
        let mut progress = false;
        for slug in transport.list_dirs(DOWN_EXCHANGE)? {
            for name in transport.list(&format!("{DOWN_EXCHANGE}/{slug}"))? {
                if parse_exchange_delta_name(&name).is_none() {
                    continue;
                }
                let dest = self.local.join(&slug).join(&name);
                if dest.exists() || self.skipped.contains(&(slug.clone(), name.clone())) {
                    continue;
                }
                let rel = format!("{DOWN_EXCHANGE}/{slug}/{name}");
                let Some(bytes) = transport.fetch(&rel)? else {
                    continue;
                };
                if let Err(e) = SkillStore::from_bytes(&bytes) {
                    crate::log_warn!(
                        "exchange delta {rel} does not parse as a skill store ({e}); \
                         skipping it"
                    );
                    self.skipped.insert((slug.clone(), name));
                    continue;
                }
                install_atomic(&dest, &bytes)?;
                progress = true;
            }
        }
        Ok(progress)
    }
}

// ------------------------------------------------------------------------
// Coordinator-side sync engines (pull worker run dirs, re-publish deltas)
// ------------------------------------------------------------------------

/// Tail-syncs one remote shard run dir into a local mirror the
/// [`MergeWatcher`] can follow: the manifest once it appears, the
/// checkpoint tail as it grows, and — only after the remote `complete`
/// marker is observed — the final skill store, snapshots, and the local
/// `complete` marker itself, in that order.
///
/// [`MergeWatcher`]: super::merge::MergeWatcher
#[derive(Debug)]
pub struct ShardPull {
    rel: String,
    mirror: PathBuf,
    results_offset: u64,
    manifest_done: bool,
    complete_done: bool,
}

impl ShardPull {
    /// Mirror global shard `shard_index` into local directory `mirror`
    /// (created; resuming a coordinator restarts the tail at the mirror's
    /// current length).
    pub fn new(mirror: &Path, shard_index: usize) -> Result<ShardPull, String> {
        ShardPull::with_rel(mirror, up_shard_rel(shard_index))
    }

    /// Mirror elastic batch `batch` into local directory `mirror`.
    pub fn new_batch(mirror: &Path, batch: usize) -> Result<ShardPull, String> {
        ShardPull::with_rel(mirror, up_batch_rel(batch))
    }

    fn with_rel(mirror: &Path, rel: String) -> Result<ShardPull, String> {
        std::fs::create_dir_all(mirror)
            .map_err(|e| format!("creating mirror {}: {e}", mirror.display()))?;
        let results_offset = std::fs::metadata(mirror.join(RESULTS))
            .map(|m| m.len())
            .unwrap_or(0);
        Ok(ShardPull {
            rel,
            mirror: mirror.to_path_buf(),
            results_offset,
            manifest_done: mirror.join(MANIFEST).exists(),
            complete_done: mirror.join(RunDir::COMPLETE_MARKER).exists(),
        })
    }

    /// The mirror carries the worker's whole slice (its `complete` marker
    /// is installed).
    pub fn is_complete(&self) -> bool {
        self.complete_done
    }

    /// Bytes beyond `results_offset` of the published segment covering it,
    /// for an offset that is not at a tile boundary (an earlier append was
    /// interrupted). `None` when the offset sits at a boundary — the
    /// exact-name fetch already covers that case.
    fn resume_mid_segment(
        &self,
        transport: &dyn RunDirTransport,
    ) -> Result<Option<Vec<u8>>, String> {
        let mut best: Option<u64> = None;
        for name in transport.list(&self.rel)? {
            if let Some(start) = parse_segment_name(&name) {
                if start < self.results_offset && best.map_or(true, |b| start > b) {
                    best = Some(start);
                }
            }
        }
        let Some(start) = best else { return Ok(None) };
        let Some(bytes) = transport.fetch(&format!("{}/{}", self.rel, segment_name(start)))?
        else {
            return Ok(None);
        };
        let skip = (self.results_offset - start) as usize;
        if skip >= bytes.len() {
            return Ok(None);
        }
        Ok(Some(bytes[skip..].to_vec()))
    }

    /// One pull cycle; returns whether anything new landed in the mirror.
    pub fn cycle(&mut self, transport: &dyn RunDirTransport) -> Result<bool, String> {
        if self.complete_done {
            return Ok(false);
        }
        let mut progress = false;
        if !self.manifest_done {
            if let Some(bytes) = transport.fetch(&format!("{}/{MANIFEST}", self.rel))? {
                install_atomic(&self.mirror.join(MANIFEST), &bytes)?;
                self.manifest_done = true;
                progress = true;
            }
        }
        // Probe remote completion *before* pulling the tail: everything the
        // worker published before its `complete` marker is then guaranteed
        // to be in this same cycle's pull, so installing the local marker
        // below can never orphan trailing cells.
        let remote_complete = transport
            .len(&format!("{}/{}", self.rel, RunDir::COMPLETE_MARKER))?
            .is_some();
        // Consume checkpoint segments in tiling order: because segments
        // tile contiguously from byte 0 and are named by their start
        // offset, the mirror's current length *is* the name of the next
        // consumable segment — drain until it is absent. (After a positive
        // completion probe above, every segment is already published, so
        // this same cycle drains the mirror to the final byte.)
        loop {
            let seg = format!("{}/{}", self.rel, segment_name(self.results_offset));
            let bytes = match transport.fetch(&seg)? {
                Some(b) => b,
                // A pull interrupted mid-append leaves the mirror *inside*
                // a tile rather than at a boundary, where the exact-name
                // fetch would miss forever; resume from the covering
                // segment's suffix instead.
                None => match self.resume_mid_segment(transport)? {
                    Some(b) => b,
                    None => break,
                },
            };
            if bytes.is_empty() {
                return Err(format!(
                    "checkpoint segment {seg} is empty — a zero-length tile can never \
                     advance the mirror; the transport root is corrupt"
                ));
            }
            use std::io::Write;
            let path = self.mirror.join(RESULTS);
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .map_err(|e| format!("appending {}: {e}", path.display()))?;
            f.write_all(&bytes)
                .map_err(|e| format!("appending {}: {e}", path.display()))?;
            self.results_offset += bytes.len() as u64;
            progress = true;
        }
        if remote_complete && self.manifest_done {
            if let Some(bytes) = transport.fetch(&format!("{}/{SKILLS}", self.rel))? {
                // Segment files land before the manifest that references
                // them, so a reader folding the mirror never observes a
                // dangling segment ref.
                for file in segment_files(&bytes) {
                    if let Some(seg) = transport.fetch(&format!("{}/{file}", self.rel))? {
                        install_atomic(&rel_path(&self.mirror, &file)?, &seg)?;
                    }
                }
                install_atomic(&self.mirror.join(SKILLS), &bytes)?;
            }
            for name in transport.list(&self.rel)? {
                if !(name.starts_with("memory_snapshot.") && name.ends_with(".json")) {
                    continue;
                }
                if let Some(bytes) = transport.fetch(&format!("{}/{name}", self.rel))? {
                    install_atomic(&self.mirror.join(&name), &bytes)?;
                }
            }
            install_atomic(&self.mirror.join(RunDir::COMPLETE_MARKER), b"complete\n")?;
            self.complete_done = true;
            progress = true;
        }
        Ok(progress)
    }
}

/// The coordinator's exchange relay: every delta a worker publishes under
/// its `up/exchange` is re-published verbatim into every *other* worker's
/// `down/exchange`, so cross-machine shards keep learning from each other
/// mid-run. Deltas are immutable and deterministic, so verbatim relay
/// preserves the exchange determinism contract bit for bit.
#[derive(Debug, Default)]
pub struct ExchangeHub {
    forwarded: BTreeSet<(usize, String, String)>,
    route_all: bool,
}

impl ExchangeHub {
    /// A hub with no relay history (a restarted coordinator re-relays
    /// identical bytes, which is harmless).
    pub fn new() -> ExchangeHub {
        ExchangeHub::default()
    }

    /// A hub for elastic fleets: slice ownership is dynamic (leases, not
    /// manifest ranges), so every delta under a worker's `up/exchange` is
    /// relayed to every other worker regardless of the manifest's
    /// placeholder ranges. A batch re-dispatched across workers can
    /// surface its delta from two sources; deltas are deterministic, so
    /// the duplicate relay publishes byte-identical content.
    pub fn new_route_all() -> ExchangeHub {
        ExchangeHub {
            forwarded: BTreeSet::new(),
            route_all: true,
        }
    }

    /// One relay cycle over the whole fleet; returns whether anything was
    /// forwarded. `workers[i]` must describe the endpoint `transports[i]`
    /// was built from.
    pub fn cycle(
        &mut self,
        workers: &[WorkerSpec],
        transports: &[Box<dyn RunDirTransport>],
    ) -> Result<bool, String> {
        let mut progress = false;
        for (src, spec) in workers.iter().enumerate() {
            let t = &transports[src];
            for slug in t.list_dirs(UP_EXCHANGE)? {
                for name in t.list(&format!("{UP_EXCHANGE}/{slug}"))? {
                    let Some((_, shard)) = parse_exchange_delta_name(&name) else {
                        continue;
                    };
                    if !self.route_all && !spec.owns(shard) {
                        // Shared-root fleets see peers' deltas in each
                        // other's listings; each delta is relayed once, by
                        // its owner's row. (Elastic hubs route everything —
                        // ownership lives in leases, not the manifest.)
                        continue;
                    }
                    let key = (src, slug.clone(), name.clone());
                    if self.forwarded.contains(&key) {
                        continue;
                    }
                    let rel = format!("{UP_EXCHANGE}/{slug}/{name}");
                    let Some(bytes) = t.fetch(&rel)? else {
                        continue;
                    };
                    // Publishes are atomic, so an unparseable delta is
                    // foreign junk, not a half transfer: warn once and
                    // never relay it, rather than wedging the fleet's
                    // whole sync loop on it.
                    if let Err(e) = SkillStore::from_bytes(&bytes) {
                        crate::log_warn!(
                            "exchange delta {rel} from worker {:?} does not parse as a \
                             skill store ({e}); not relaying it",
                            spec.id
                        );
                        self.forwarded.insert(key);
                        continue;
                    }
                    for (dst, dt) in transports.iter().enumerate() {
                        if dst == src {
                            continue;
                        }
                        dt.publish(&format!("{DOWN_EXCHANGE}/{slug}/{name}"), &bytes)?;
                    }
                    self.forwarded.insert(key);
                    progress = true;
                }
            }
        }
        Ok(progress)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ks-transport-{tag}-{}", std::process::id()))
    }

    fn manifest_text(total: usize, rows: &[(&str, usize, usize)]) -> String {
        let workers: Vec<String> = rows
            .iter()
            .map(|(id, lo, hi)| {
                format!(
                    r#"{{"id":"{id}","shard_lo":{lo},"shard_hi":{hi},"transport":{{"kind":"mirror-dir","root":"/tmp/ks-mt-{id}"}}}}"#
                )
            })
            .collect();
        format!(
            r#"{{"version":1,"total_shards":{total},"workers":[{}]}}"#,
            workers.join(",")
        )
    }

    #[test]
    fn manifest_parses_and_validates_cover() {
        let m = WorkerManifest::parse(&manifest_text(4, &[("a", 0, 1), ("b", 2, 3)])).unwrap();
        assert_eq!(m.total_shards, 4);
        assert_eq!(m.workers.len(), 2);
        assert!(m.worker("a").unwrap().owns(1));
        assert!(!m.worker("a").unwrap().owns(2));
        assert_eq!(m.worker_ids(), vec!["a", "b"]);
        assert!(m.worker("missing").is_none());
    }

    #[test]
    fn manifest_parses_and_validates_per_worker_devices() {
        let m = WorkerManifest::parse(
            r#"{"version":1,"total_shards":2,"workers":[
              {"id":"a","shard_lo":0,"shard_hi":0,"device":"tpu-like",
               "transport":{"kind":"mirror-dir","root":"/tmp/ks-md-a"}},
              {"id":"b","shard_lo":1,"shard_hi":1,
               "transport":{"kind":"mirror-dir","root":"/tmp/ks-md-b"}}
            ]}"#,
        )
        .unwrap();
        assert_eq!(m.worker("a").unwrap().device.as_deref(), Some("tpu-like"));
        assert_eq!(m.worker("b").unwrap().device, None, "device is optional per row");

        let err = WorkerManifest::parse(
            r#"{"total_shards":1,"workers":[{"id":"a","shard_lo":0,"shard_hi":0,
                "device":"voodoo2-like",
                "transport":{"kind":"mirror-dir","root":"/tmp/ks-md-a"}}]}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown device preset") && err.contains("cpu-like"), "{err}");
    }

    #[test]
    fn manifest_refuses_duplicate_ids() {
        let err =
            WorkerManifest::parse(&manifest_text(4, &[("a", 0, 1), ("a", 2, 3)])).unwrap_err();
        assert!(err.contains("duplicate worker id"), "{err}");
    }

    #[test]
    fn manifest_refuses_overlap_and_gaps() {
        let err =
            WorkerManifest::parse(&manifest_text(4, &[("a", 0, 2), ("b", 2, 3)])).unwrap_err();
        assert!(err.contains("overlapping") && err.contains("shard 2"), "{err}");
        let err =
            WorkerManifest::parse(&manifest_text(4, &[("a", 0, 1), ("b", 3, 3)])).unwrap_err();
        assert!(err.contains("covered by no worker") && err.contains('2'), "{err}");
        // A top-end gap (ranges legal, total too big) is still a gap.
        let err =
            WorkerManifest::parse(&manifest_text(5, &[("a", 0, 1), ("b", 2, 3)])).unwrap_err();
        assert!(err.contains("covered by no worker"), "{err}");
    }

    #[test]
    fn manifest_refuses_malformed_rows() {
        let err =
            WorkerManifest::parse(&manifest_text(2, &[("a", 1, 0), ("b", 1, 1)])).unwrap_err();
        assert!(err.contains("shard_lo"), "{err}");
        let err =
            WorkerManifest::parse(&manifest_text(2, &[("a", 0, 0), ("b", 1, 5)])).unwrap_err();
        assert!(err.contains("total_shards is 2"), "{err}");
        let err = WorkerManifest::parse(&manifest_text(0, &[])).unwrap_err();
        assert!(err.contains("total_shards must be >= 1"), "{err}");
        let err = WorkerManifest::parse(r#"{"total_shards":1,"workers":[]}"#).unwrap_err();
        assert!(err.contains("at least one worker"), "{err}");
        let err = WorkerManifest::parse(
            r#"{"total_shards":1,"workers":[{"id":"a","shard_lo":0,"shard_hi":0,
                "transport":{"kind":"carrier-pigeon","root":"/tmp/x"}}]}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown transport kind"), "{err}");
        assert!(WorkerManifest::load(Path::new("/no/such/manifest.json")).is_err());
    }

    #[test]
    fn mirror_dir_roundtrips_atomically() {
        let root = tmp_dir("mirror");
        let _ = std::fs::remove_dir_all(&root);
        let t = MirrorDir::new(&root).unwrap();
        assert!(t.fetch("a/b.txt").unwrap().is_none());
        assert!(t.len("a/b.txt").unwrap().is_none());
        assert_eq!(t.list("a").unwrap(), Vec::<String>::new());
        t.publish("a/b.txt", b"hello\nworld\n").unwrap();
        assert_eq!(t.fetch("a/b.txt").unwrap().unwrap(), b"hello\nworld\n");
        assert_eq!(t.len("a/b.txt").unwrap(), Some(12));
        assert_eq!(t.fetch_from("a/b.txt", 6).unwrap().unwrap(), b"world\n");
        assert_eq!(t.fetch_from("a/b.txt", 99).unwrap().unwrap(), b"");
        t.publish("a/b.txt", b"rewritten\n").unwrap();
        assert_eq!(t.fetch("a/b.txt").unwrap().unwrap(), b"rewritten\n");
        assert_eq!(t.list("a").unwrap(), vec!["b.txt".to_string()]);
        assert_eq!(t.list_dirs("").unwrap(), vec!["a".to_string()]);
        // The staging area never shows up in listings.
        assert!(!t.list_dirs("").unwrap().contains(&".staging".to_string()));
        // MirrorDir is deliberately opaque; LocalFs is the zero-copy one.
        assert!(t.local_dir("a").is_none());
        let lt = LocalFs::new(&root).unwrap();
        assert_eq!(lt.local_dir("a").unwrap(), root.join("a"));
        // Escapes are refused.
        assert!(t.publish("../evil", b"x").is_err());
        assert!(t.fetch("/abs").is_err());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn mirror_dir_check_detects_vanished_root() {
        let root = tmp_dir("vanish");
        let _ = std::fs::remove_dir_all(&root);
        let t = MirrorDir::new(&root).unwrap();
        t.check().unwrap();
        std::fs::remove_dir_all(&root).unwrap();
        let err = t.check().unwrap_err();
        assert!(err.contains("disappeared"), "{err}");
    }

    #[test]
    fn interrupted_publish_is_invisible_and_retryable() {
        // The fault hook cuts the first matching publish off mid-file (the
        // staging file holds half the bytes); nothing may become visible,
        // and the retry must land the full contents.
        let root = tmp_dir("fault");
        let _ = std::fs::remove_dir_all(&root);
        let marker = tmp_dir("fault-marker");
        let _ = std::fs::remove_file(&marker);
        let t = MirrorDir::new(&root)
            .unwrap()
            .with_fault_hook("unique-fault-probe", &marker);
        let err = t.publish("x/unique-fault-probe.bin", b"0123456789").unwrap_err();
        assert!(err.contains("interruption"), "{err}");
        assert!(marker.exists(), "the simulated interruption must have fired");
        assert!(
            t.fetch("x/unique-fault-probe.bin").unwrap().is_none(),
            "a torn transfer must never become visible"
        );
        t.publish("x/unique-fault-probe.bin", b"0123456789").unwrap();
        assert_eq!(
            t.fetch("x/unique-fault-probe.bin").unwrap().unwrap(),
            b"0123456789"
        );
        let _ = std::fs::remove_file(&marker);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn shard_push_publishes_at_newline_boundaries_and_complete_last() {
        let root = tmp_dir("push");
        let _ = std::fs::remove_dir_all(&root);
        let local = root.join("local");
        std::fs::create_dir_all(&local).unwrap();
        let t = MirrorDir::new(&root.join("remote")).unwrap();
        let mut push = ShardPush::new(&local, 0, &t).unwrap();

        std::fs::write(local.join(MANIFEST), b"{\"m\":1}\n").unwrap();
        std::fs::write(local.join(RESULTS), b"line-one\nline-two\ntorn-tai").unwrap();
        assert!(push.cycle(&t).unwrap());
        assert_eq!(t.fetch("up/shard-0/manifest.json").unwrap().unwrap(), b"{\"m\":1}\n");
        assert_eq!(
            t.fetch(&format!("up/shard-0/{}", segment_name(0))).unwrap().unwrap(),
            b"line-one\nline-two\n",
            "only the newline-terminated prefix may be published"
        );
        assert!(
            t.fetch("up/shard-0/results.jsonl").unwrap().is_none(),
            "the checkpoint is never published whole-file"
        );
        assert!(!push.is_complete());
        assert!(!push.cycle(&t).unwrap(), "no growth, nothing to publish");

        // Completing the torn line and marking complete publishes the rest
        // as a *second* immutable segment (only the new bytes travel), with
        // the marker observable only after the data.
        std::fs::write(local.join(RESULTS), b"line-one\nline-two\ntorn-tail-done\n").unwrap();
        std::fs::write(local.join(SKILLS), b"{\"s\":1}\n").unwrap();
        std::fs::write(local.join(RunDir::COMPLETE_MARKER), b"complete\n").unwrap();
        assert!(push.cycle(&t).unwrap());
        assert!(push.is_complete());
        assert_eq!(
            t.fetch(&format!("up/shard-0/{}", segment_name(18))).unwrap().unwrap(),
            b"torn-tail-done\n"
        );
        assert_eq!(push.results_pushed(), 33);
        assert!(t.len("up/shard-0/complete").unwrap().is_some());
        assert!(
            t.fetch("up/shard-0/results.jsonl").unwrap().is_none(),
            "still no whole-file checkpoint after completion"
        );

        // A fresh push over a transport that is *ahead* of the local
        // checkpoint refuses to publish (stale/foreign root).
        std::fs::write(local.join(RESULTS), b"line-one\n").unwrap();
        let mut stale = ShardPush::new(&local, 0, &t).unwrap();
        assert_eq!(stale.results_pushed(), 33, "resumes from the segment cover");
        let err = stale.cycle(&t).unwrap_err();
        assert!(err.contains("different"), "{err}");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn push_refuses_pre_segment_whole_file_roots_and_gapped_tilings() {
        let root = tmp_dir("push-layout");
        let _ = std::fs::remove_dir_all(&root);
        let local = root.join("local");
        std::fs::create_dir_all(&local).unwrap();
        let t = MirrorDir::new(&root.join("remote")).unwrap();
        t.publish("up/shard-0/results.jsonl", b"old\n").unwrap();
        let err = ShardPush::new(&local, 0, &t).unwrap_err();
        assert!(err.contains("pre-segment"), "{err}");

        let t2 = MirrorDir::new(&root.join("remote2")).unwrap();
        t2.publish(&format!("up/shard-0/{}", segment_name(7)), b"gapped\n").unwrap();
        let err = ShardPush::new(&local, 0, &t2).unwrap_err();
        assert!(err.contains("tile contiguously"), "{err}");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn push_detects_same_length_rewrite_without_mtime() {
        // Two same-length skills.json writes inside the filesystem's mtime
        // granularity: the old (len, mtime) probe skipped the second one —
        // byte comparison must publish it.
        let root = tmp_dir("push-rewrite");
        let _ = std::fs::remove_dir_all(&root);
        let local = root.join("local");
        std::fs::create_dir_all(&local).unwrap();
        let t = MirrorDir::new(&root.join("remote")).unwrap();
        let mut push = ShardPush::new(&local, 0, &t).unwrap();
        std::fs::write(local.join(SKILLS), b"{\"v\":1}\n").unwrap();
        assert!(push.cycle(&t).unwrap());
        std::fs::write(local.join(SKILLS), b"{\"v\":2}\n").unwrap();
        assert!(push.cycle(&t).unwrap(), "same-length rewrite must be detected");
        assert_eq!(t.fetch("up/shard-0/skills.json").unwrap().unwrap(), b"{\"v\":2}\n");
        assert!(!push.cycle(&t).unwrap(), "unchanged bytes are not re-published");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn segment_files_reads_manifest_refs_and_tolerates_flat_or_garbage() {
        let manifest = b"{\"segments\":[{\"cases\":1,\"file\":\"skills.segments/seg-000001.json\",\
            \"generation\":1,\"observations\":2},{\"cases\":2,\
            \"file\":\"skills.segments/seg-000002.json\",\"generation\":2,\"observations\":3}],\
            \"version\":4}\n";
        assert_eq!(
            segment_files(manifest),
            vec![
                "skills.segments/seg-000001.json".to_string(),
                "skills.segments/seg-000002.json".to_string(),
            ]
        );
        assert!(segment_files(b"{\"version\":4,\"segments\":[]}\n").is_empty());
        assert!(segment_files(b"{\"s\":1}\n").is_empty(), "flat v3-era store");
        assert!(segment_files(b"not json at all").is_empty());
    }

    #[test]
    fn segmented_skill_store_travels_as_a_directory() {
        // A v4 manifest references immutable segment files; push publishes
        // each referenced file (once) alongside the manifest, and pull
        // installs the segments before the manifest so the mirrored store
        // never has a dangling ref.
        let root = tmp_dir("seg-sync");
        let _ = std::fs::remove_dir_all(&root);
        let local = root.join("local");
        std::fs::create_dir_all(local.join("skills.segments")).unwrap();
        let t = MirrorDir::new(&root.join("remote")).unwrap();

        let seg = b"{\"seg\":1}\n";
        let manifest =
            b"{\"segments\":[{\"file\":\"skills.segments/seg-000001.json\"}],\"version\":4}\n";
        std::fs::write(local.join("skills.segments/seg-000001.json"), seg).unwrap();
        std::fs::write(local.join(SKILLS), manifest).unwrap();
        let mut push = ShardPush::new(&local, 0, &t).unwrap();
        assert!(push.cycle(&t).unwrap());
        assert_eq!(
            t.fetch("up/shard-0/skills.segments/seg-000001.json").unwrap().unwrap(),
            seg
        );
        assert_eq!(t.fetch("up/shard-0/skills.json").unwrap().unwrap(), manifest);
        assert!(!push.cycle(&t).unwrap(), "segments and manifest are pushed once");

        t.publish("up/shard-0/manifest.json", b"{\"m\":1}\n").unwrap();
        t.publish("up/shard-0/complete", b"complete\n").unwrap();
        let mirror = root.join("mirror");
        let mut pull = ShardPull::new(&mirror, 0).unwrap();
        assert!(pull.cycle(&t).unwrap());
        assert!(pull.is_complete());
        assert_eq!(
            std::fs::read(mirror.join("skills.segments/seg-000001.json")).unwrap(),
            seg
        );
        assert_eq!(std::fs::read(mirror.join(SKILLS)).unwrap(), manifest);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn push_rejects_traversal_segment_refs() {
        let root = tmp_dir("seg-traversal");
        let _ = std::fs::remove_dir_all(&root);
        let local = root.join("local");
        std::fs::create_dir_all(&local).unwrap();
        let t = MirrorDir::new(&root.join("remote")).unwrap();
        std::fs::write(
            local.join(SKILLS),
            b"{\"segments\":[{\"file\":\"../escape.json\"}],\"version\":4}\n",
        )
        .unwrap();
        let mut push = ShardPush::new(&local, 0, &t).unwrap();
        let err = push.cycle(&t).unwrap_err();
        assert!(err.contains("invalid transport path"), "{err}");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn batch_push_waits_for_local_catch_up_after_redispatch() {
        // A re-dispatched batch recomputes deterministic bytes from scratch:
        // until the local checkpoint reaches the cover a dead attempt
        // already published, the push must idle (no error, no publish) —
        // then resume publishing exactly past the cover. A *static* shard
        // in the same state stays a loud stale-root error.
        let root = tmp_dir("push-catchup");
        let _ = std::fs::remove_dir_all(&root);
        let local = root.join("local");
        std::fs::create_dir_all(&local).unwrap();
        let t = MirrorDir::new(&root.join("remote")).unwrap();
        t.publish(&format!("up/batch-3/{}", segment_name(0)), b"a 1\nbb 2\n").unwrap();

        let mut push = ShardPush::new_batch(&local, 3, &t).unwrap();
        assert_eq!(push.results_pushed(), 9);
        std::fs::write(local.join(RESULTS), b"a 1\n").unwrap();
        assert!(!push.cycle(&t).unwrap(), "behind the cover: nothing to publish yet");
        assert_eq!(push.results_pushed(), 9);
        std::fs::write(local.join(RESULTS), b"a 1\nbb 2\nccc 3\n").unwrap();
        assert!(push.cycle(&t).unwrap());
        assert_eq!(push.results_pushed(), 15);
        assert_eq!(
            t.fetch(&format!("up/batch-3/{}", segment_name(9))).unwrap().unwrap(),
            b"ccc 3\n"
        );

        // Claiming to be complete while still short of the cover is a
        // foreign-root error even for a batch.
        let local2 = root.join("local2");
        std::fs::create_dir_all(&local2).unwrap();
        std::fs::write(local2.join(RESULTS), b"a 1\n").unwrap();
        std::fs::write(local2.join(RunDir::COMPLETE_MARKER), b"complete\n").unwrap();
        let mut short = ShardPush::new_batch(&local2, 3, &t).unwrap();
        let err = short.cycle(&t).unwrap_err();
        assert!(err.contains("refusing to publish over it"), "{err}");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn shard_pull_mirrors_and_installs_complete_last() {
        let root = tmp_dir("pull");
        let _ = std::fs::remove_dir_all(&root);
        let t = MirrorDir::new(&root.join("remote")).unwrap();
        let mirror = root.join("mirror");
        let mut pull = ShardPull::new(&mirror, 3).unwrap();

        assert!(!pull.cycle(&t).unwrap(), "nothing remote yet");
        t.publish("up/shard-3/manifest.json", b"{\"m\":1}\n").unwrap();
        t.publish(&format!("up/shard-3/{}", segment_name(0)), b"one\n").unwrap();
        assert!(pull.cycle(&t).unwrap());
        assert_eq!(std::fs::read(mirror.join(RESULTS)).unwrap(), b"one\n");
        assert!(!pull.is_complete());

        t.publish(&format!("up/shard-3/{}", segment_name(4)), b"two\n").unwrap();
        t.publish("up/shard-3/skills.json", b"{\"s\":1}\n").unwrap();
        t.publish("up/shard-3/complete", b"complete\n").unwrap();
        assert!(pull.cycle(&t).unwrap());
        assert!(pull.is_complete());
        assert_eq!(std::fs::read(mirror.join(RESULTS)).unwrap(), b"one\ntwo\n");
        assert_eq!(std::fs::read(mirror.join(SKILLS)).unwrap(), b"{\"s\":1}\n");
        assert!(mirror.join(RunDir::COMPLETE_MARKER).exists());

        // A restarted coordinator resumes the tail where the mirror ends.
        let resumed = ShardPull::new(&mirror, 3).unwrap();
        assert!(resumed.is_complete());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn pull_resumes_mid_segment_after_interrupted_append() {
        // A coordinator killed halfway through appending a segment leaves
        // the mirror inside a tile; the next cycle must append only the
        // covering segment's suffix, not stall or duplicate bytes.
        let root = tmp_dir("pull-mid");
        let _ = std::fs::remove_dir_all(&root);
        let t = MirrorDir::new(&root.join("remote")).unwrap();
        t.publish(&format!("up/shard-0/{}", segment_name(0)), b"alpha\nbeta\n").unwrap();
        let mirror = root.join("mirror");
        std::fs::create_dir_all(&mirror).unwrap();
        std::fs::write(mirror.join(RESULTS), b"alph").unwrap();
        let mut pull = ShardPull::new(&mirror, 0).unwrap();
        assert!(pull.cycle(&t).unwrap());
        assert_eq!(std::fs::read(mirror.join(RESULTS)).unwrap(), b"alpha\nbeta\n");
        assert!(!pull.cycle(&t).unwrap(), "caught up");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn exchange_push_pull_and_hub_route_by_ownership() {
        let root = tmp_dir("exchange");
        let _ = std::fs::remove_dir_all(&root);
        let specs = vec![
            WorkerSpec {
                id: "a".to_string(),
                shard_lo: 0,
                shard_hi: 0,
                transport: TransportSpec {
                    kind: TransportKind::MirrorDir,
                    root: root.join("ta"),
                },
                device: None,
            },
            WorkerSpec {
                id: "b".to_string(),
                shard_lo: 1,
                shard_hi: 1,
                transport: TransportSpec {
                    kind: TransportKind::MirrorDir,
                    root: root.join("tb"),
                },
                device: None,
            },
        ];
        let transports: Vec<Box<dyn RunDirTransport>> =
            specs.iter().map(|s| s.transport.build().unwrap()).collect();

        // Worker a publishes its shard-0 delta for epoch 0.
        let delta = SkillStore::new().canonical_bytes();
        let local_a = root.join("ex-a");
        std::fs::create_dir_all(local_a.join("kernelskill")).unwrap();
        std::fs::write(local_a.join("kernelskill/epoch-0.shard-0.json"), &delta).unwrap();
        // A stray non-delta file and a peer's installed delta are ignored.
        std::fs::write(local_a.join("kernelskill/notes.txt"), b"x").unwrap();
        std::fs::write(local_a.join("kernelskill/epoch-0.shard-1.json"), &delta).unwrap();
        let mut push = ExchangePush::new(&local_a, vec![0]);
        assert!(push.cycle(transports[0].as_ref()).unwrap());
        assert_eq!(
            transports[0].list("up/exchange/kernelskill").unwrap(),
            vec!["epoch-0.shard-0.json".to_string()],
            "only owned deltas are published"
        );
        assert!(!push.cycle(transports[0].as_ref()).unwrap(), "pushed once");

        // The hub relays a's delta into b's down/exchange — and not back
        // into a's.
        let mut hub = ExchangeHub::new();
        assert!(hub.cycle(&specs, &transports).unwrap());
        assert!(!hub.cycle(&specs, &transports).unwrap(), "relayed once");
        assert_eq!(
            transports[1].list("down/exchange/kernelskill").unwrap(),
            vec!["epoch-0.shard-0.json".to_string()]
        );
        assert!(transports[0].list("down/exchange/kernelskill").unwrap().is_empty());

        // Worker b installs it where its shards wait for it.
        let local_b = root.join("ex-b");
        let mut pull = ExchangePull::new(&local_b);
        assert!(pull.cycle(transports[1].as_ref()).unwrap());
        assert_eq!(
            std::fs::read(local_b.join("kernelskill/epoch-0.shard-0.json")).unwrap(),
            delta
        );
        assert!(!pull.cycle(transports[1].as_ref()).unwrap(), "installed once");

        // A route-all hub (elastic mode) ignores the manifest ranges: b's
        // installed copy of a's delta is relayed from b's row too — the
        // bytes are identical, so the duplicate is invisible.
        let mut hub_all = ExchangeHub::new_route_all();
        assert!(hub_all.cycle(&specs, &transports).unwrap());
        assert_eq!(
            transports[0].list("down/exchange/kernelskill").unwrap(),
            vec!["epoch-0.shard-0.json".to_string()],
            "route-all relays regardless of manifest ownership"
        );
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn segment_batch_and_lease_names_roundtrip() {
        assert_eq!(parse_segment_name(&segment_name(0)), Some(0));
        assert_eq!(parse_segment_name(&segment_name(123456)), Some(123456));
        assert_eq!(parse_segment_name("results.jsonl"), None);
        assert_eq!(parse_segment_name("results.seg-12.jsonl"), None, "unpadded");
        assert_eq!(parse_up_batch_name("batch-7"), Some(7));
        assert_eq!(parse_up_batch_name("shard-7"), None);
        assert_eq!(parse_lease_name(&lease_name(3, 1)), Some((3, 1, false)));
        assert_eq!(parse_lease_name(&lease_expired_name(3, 1)), Some((3, 1, true)));
        assert_eq!(parse_lease_name("batch-3.json"), None);
        assert_eq!(parse_lease_name("junk"), None);
    }

    #[test]
    fn publish_excl_first_wins_under_race() {
        let root = tmp_dir("excl");
        let _ = std::fs::remove_dir_all(&root);
        let t = MirrorDir::new(&root).unwrap();
        assert!(t.publish_excl("leases/x.json", b"first\n").unwrap());
        assert!(!t.publish_excl("leases/x.json", b"second\n").unwrap());
        assert_eq!(t.fetch("leases/x.json").unwrap().unwrap(), b"first\n");

        // Many threads race one path: exactly one wins.
        let wins = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let t = MirrorDir::new(&root).unwrap();
                    if t.publish_excl("leases/raced.json", b"claim\n").unwrap() {
                        wins.fetch_add(1, Ordering::SeqCst);
                    }
                });
            }
        });
        assert_eq!(wins.load(Ordering::SeqCst), 1);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn lease_roundtrip_and_parse_errors() {
        let lease = Lease {
            batch: 4,
            attempt: 2,
            worker: "w1".to_string(),
            progress: 9876543210,
            done: false,
        };
        let parsed = Lease::parse(&lease.to_bytes()).unwrap();
        assert_eq!(parsed, lease);
        assert_eq!(lease.rel(), "leases/batch-4.attempt-2.json");
        assert!(Lease::parse(b"not json").is_err());
        assert!(Lease::parse(b"{\"batch\":1}").is_err());
    }

    #[test]
    fn lease_board_claim_expire_redispatch_lifecycle() {
        let root = tmp_dir("lease-life");
        let _ = std::fs::remove_dir_all(&root);
        let t = MirrorDir::new(&root).unwrap();

        // Fresh board: everything claimable, lowest batch claimed first.
        let board = read_lease_board(&t, 3).unwrap();
        assert!(board.iter().all(|b| b.claimable()));
        let lease = claim_next_batch(&t, &board, "w0").unwrap().unwrap();
        assert_eq!((lease.batch, lease.attempt), (0, 0));

        // Re-read: batch 0 held (not claimable), 1 and 2 still open.
        let board = read_lease_board(&t, 3).unwrap();
        assert!(!board[0].claimable());
        assert_eq!(board[0].latest.as_ref().unwrap().worker, "w0");
        let lease1 = claim_next_batch(&t, &board, "w1").unwrap().unwrap();
        assert_eq!((lease1.batch, lease1.attempt), (1, 0));

        // Heartbeat: the holder overwrites its own lease with progress.
        let mut hb = lease.clone();
        hb.progress = 42;
        t.publish(&hb.rel(), &hb.to_bytes()).unwrap();
        let board = read_lease_board(&t, 3).unwrap();
        assert_eq!(board[0].latest.as_ref().unwrap().progress, 42);

        // Coordinator expires attempt 0 of batch 0: claimable again, and
        // the re-claim gets attempt 1 — the audit trail of the re-dispatch.
        assert!(expire_lease(&t, 0, 0).unwrap());
        assert!(!expire_lease(&t, 0, 0).unwrap(), "expiry marker is idempotent");
        let board = read_lease_board(&t, 3).unwrap();
        assert!(board[0].claimable());
        let re = claim_next_batch(&t, &board, "w1").unwrap().unwrap();
        assert_eq!((re.batch, re.attempt), (0, 1));

        // The expired-then-recovered straggler finishes late: its done on
        // attempt 0 still marks the batch done (duplicate execution merges
        // bit-identically downstream).
        let mut done0 = hb.clone();
        done0.done = true;
        t.publish(&done0.rel(), &done0.to_bytes()).unwrap();
        let board = read_lease_board(&t, 3).unwrap();
        assert!(board[0].done);
        assert!(!board[0].claimable(), "done batches are never re-claimed");

        // A lease for a batch beyond the declared count is a foreign root.
        let stray = Lease {
            batch: 9,
            attempt: 0,
            worker: "w9".to_string(),
            progress: 0,
            done: false,
        };
        t.publish(&stray.rel(), &stray.to_bytes()).unwrap();
        let err = read_lease_board(&t, 3).unwrap_err();
        assert!(err.contains("different run"), "{err}");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn racing_claimants_partition_the_board_exactly() {
        // N workers hammer claim_next_batch over one shared lease root:
        // every batch ends up claimed by exactly one attempt-0 lease.
        let root = tmp_dir("lease-race");
        let _ = std::fs::remove_dir_all(&root);
        let total = 12usize;
        std::thread::scope(|s| {
            for w in 0..4 {
                let root = &root;
                s.spawn(move || {
                    let t = MirrorDir::new(root).unwrap();
                    let me = format!("w{w}");
                    loop {
                        let board = read_lease_board(&t, total).unwrap();
                        if board.iter().all(|b| !b.claimable()) {
                            break;
                        }
                        let _ = claim_next_batch(&t, &board, &me).unwrap();
                    }
                });
            }
        });
        let t = MirrorDir::new(&root).unwrap();
        let board = read_lease_board(&t, total).unwrap();
        for state in &board {
            assert_eq!(
                state.attempts, 1,
                "batch {} must be claimed by exactly one attempt",
                state.batch
            );
            assert!(state.latest.is_some());
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    fn elastic_manifest_text() -> String {
        r#"{"version":1,"total_batches":6,
            "lease":{"kind":"mirror-dir","root":"/tmp/ks-el-lease"},
            "workers":[
              {"id":"w0","transport":{"kind":"mirror-dir","root":"/tmp/ks-el-w0"}},
              {"id":"w1","transport":{"kind":"mirror-dir","root":"/tmp/ks-el-w1"}}
        ]}"#
            .to_string()
    }

    #[test]
    fn elastic_manifest_parses_and_guards_mode_mixing() {
        let m = WorkerManifest::parse(&elastic_manifest_text()).unwrap();
        assert!(m.is_elastic());
        assert_eq!(m.total_batches, 6);
        assert_eq!(m.total_shards, 0);
        assert_eq!(m.worker_ids(), vec!["w0", "w1"]);

        // total_shards in an elastic manifest is a mode mix-up.
        let err = WorkerManifest::parse(
            r#"{"total_shards":2,"total_batches":2,
                "lease":{"kind":"mirror-dir","root":"/tmp/l"},
                "workers":[{"id":"a","transport":{"kind":"mirror-dir","root":"/tmp/a"}}]}"#,
        )
        .unwrap_err();
        assert!(err.contains("total_batches, not total_shards"), "{err}");

        // total_batches without a lease transport is too.
        let err = WorkerManifest::parse(
            r#"{"total_batches":2,
                "workers":[{"id":"a","transport":{"kind":"mirror-dir","root":"/tmp/a"}}]}"#,
        )
        .unwrap_err();
        assert!(err.contains("requires a lease transport"), "{err}");

        // Shard ranges on elastic workers are refused.
        let err = WorkerManifest::parse(
            r#"{"total_batches":2,"lease":{"kind":"mirror-dir","root":"/tmp/l"},
                "workers":[{"id":"a","shard_lo":0,"shard_hi":1,
                  "transport":{"kind":"mirror-dir","root":"/tmp/a"}}]}"#,
        )
        .unwrap_err();
        assert!(err.contains("must not declare shard ranges"), "{err}");

        // And a batch count of zero is refused.
        let err = WorkerManifest::parse(
            r#"{"total_batches":0,"lease":{"kind":"mirror-dir","root":"/tmp/l"},
                "workers":[{"id":"a","transport":{"kind":"mirror-dir","root":"/tmp/a"}}]}"#,
        )
        .unwrap_err();
        assert!(err.contains("total_batches must be >= 1"), "{err}");
    }
}
