//! Suite runner: fan (strategy x task x seed) over the thread pool and
//! aggregate per-level statistics — the engine behind every table bench.

use super::loop_runner::{run_task, LoopConfig, TaskResult};
use crate::baselines::Strategy;
use crate::bench_suite::Task;
use crate::util::pool;

/// All results of one strategy over a task set (possibly several seeds).
#[derive(Debug, Clone)]
pub struct SuiteResult {
    pub strategy: &'static str,
    pub results: Vec<TaskResult>,
}

/// Run one strategy across `tasks` for each seed in `seeds`, in parallel.
pub fn run_suite(
    tasks: &[Task],
    strategy: &Strategy,
    cfg: &LoopConfig,
    seeds: &[u64],
    workers: usize,
) -> SuiteResult {
    // Work items: (task index, seed) — tasks is shared by reference.
    let items: Vec<(usize, u64)> = (0..tasks.len())
        .flat_map(|t| seeds.iter().map(move |s| (t, *s)))
        .collect();
    let results = pool::parallel_map(&items, workers, |&(ti, seed)| {
        let mut c = cfg.clone();
        c.run_seed = seed;
        run_task(&tasks[ti], strategy, &c)
    });
    SuiteResult {
        strategy: strategy.name,
        results,
    }
}

/// Run several strategies over the same tasks/seeds.
pub fn run_matrix(
    tasks: &[Task],
    strategies: &[Strategy],
    cfg: &LoopConfig,
    seeds: &[u64],
    workers: usize,
) -> Vec<SuiteResult> {
    strategies
        .iter()
        .map(|s| run_suite(tasks, s, cfg, seeds, workers))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::bench_suite;

    #[test]
    fn parallel_equals_serial() {
        let tasks: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(8).collect();
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        let par = run_suite(&tasks, &strat, &cfg, &[0], 4);
        let ser = run_suite(&tasks, &strat, &cfg, &[0], 1);
        for (a, b) in par.results.iter().zip(&ser.results) {
            assert_eq!(a.task_id, b.task_id);
            assert_eq!(a.best_speedup, b.best_speedup, "{}", a.task_id);
        }
    }

    #[test]
    fn seeds_multiply_results() {
        let tasks: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(4).collect();
        let r = run_suite(
            &tasks,
            &baselines::kernelskill(),
            &LoopConfig::default(),
            &[0, 1, 2],
            4,
        );
        assert_eq!(r.results.len(), 12);
    }
}
