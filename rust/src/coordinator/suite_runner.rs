//! Suite runner: fan (strategy x task x seed) over the work-stealing
//! scheduler and aggregate per-level statistics — the engine behind every
//! table bench.
//!
//! v2: orchestration lives in `coordinator::scheduler` (incremental JSONL
//! checkpointing, resume, persistent skill memory). The plain
//! [`run_suite`]/[`run_matrix`] entry points keep the v1 signature and
//! semantics; [`run_suite_with`]/[`run_matrix_with`] expose the
//! orchestration options, including sharded execution: with
//! `SuiteOptions::shard` set, each process runs a disjoint round-robin
//! slice of every strategy's cell matrix into its own run dir, and
//! `coordinator::merge` reunites the shards afterwards.

use super::loop_runner::{LoopConfig, TaskResult};
use super::scheduler::{self, SuiteOptions};
use crate::baselines::Strategy;
use crate::bench_suite::Task;

/// All results of one strategy over a task set (possibly several seeds).
#[derive(Debug, Clone)]
pub struct SuiteResult {
    /// Strategy the suite ran.
    pub strategy: &'static str,
    /// One result per (task, seed) cell, task-major.
    pub results: Vec<TaskResult>,
}

/// Run one strategy across `tasks` for each seed in `seeds`, in parallel.
pub fn run_suite(
    tasks: &[Task],
    strategy: &Strategy,
    cfg: &LoopConfig,
    seeds: &[u64],
    workers: usize,
) -> SuiteResult {
    // No run dir is involved, but cfg.memory_dir can still make this do IO;
    // surface the real error instead of pretending it cannot happen.
    run_suite_with(tasks, strategy, cfg, seeds, workers, &SuiteOptions::default())
        .unwrap_or_else(|e| panic!("suite run failed: {e}"))
}

/// [`run_suite`] with orchestration options (checkpoint dir, resume,
/// stop-after). Results are always in deterministic (task-major,
/// seed-minor) order, regardless of worker count or restore path.
pub fn run_suite_with(
    tasks: &[Task],
    strategy: &Strategy,
    cfg: &LoopConfig,
    seeds: &[u64],
    workers: usize,
    opts: &SuiteOptions,
) -> Result<SuiteResult, String> {
    let results = scheduler::run_strategy(tasks, strategy, cfg, seeds, workers, opts)?;
    Ok(SuiteResult {
        strategy: strategy.name,
        results,
    })
}

/// Run several strategies over the same tasks/seeds.
pub fn run_matrix(
    tasks: &[Task],
    strategies: &[Strategy],
    cfg: &LoopConfig,
    seeds: &[u64],
    workers: usize,
) -> Vec<SuiteResult> {
    run_matrix_with(tasks, strategies, cfg, seeds, workers, &SuiteOptions::default())
        .unwrap_or_else(|e| panic!("matrix run failed: {e}"))
}

/// [`run_matrix`] with orchestration options. All strategies share one run
/// directory; cells are keyed by strategy, so a resumed matrix picks up
/// exactly where it was killed.
pub fn run_matrix_with(
    tasks: &[Task],
    strategies: &[Strategy],
    cfg: &LoopConfig,
    seeds: &[u64],
    workers: usize,
    opts: &SuiteOptions,
) -> Result<Vec<SuiteResult>, String> {
    strategies
        .iter()
        .map(|s| run_suite_with(tasks, s, cfg, seeds, workers, opts))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::bench_suite;

    #[test]
    fn parallel_equals_serial() {
        let tasks: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(8).collect();
        let strat = baselines::kernelskill();
        let cfg = LoopConfig::default();
        let par = run_suite(&tasks, &strat, &cfg, &[0], 4);
        let ser = run_suite(&tasks, &strat, &cfg, &[0], 1);
        for (a, b) in par.results.iter().zip(&ser.results) {
            assert_eq!(a.task_id, b.task_id);
            assert_eq!(a.best_speedup, b.best_speedup, "{}", a.task_id);
        }
    }

    #[test]
    fn seeds_multiply_results() {
        let tasks: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(4).collect();
        let r = run_suite(
            &tasks,
            &baselines::kernelskill(),
            &LoopConfig::default(),
            &[0, 1, 2],
            4,
        );
        assert_eq!(r.results.len(), 12);
    }

    #[test]
    fn sharded_matrix_covers_every_strategy_slice() {
        // Each shard runs its slice of *every* strategy's matrix; unioning
        // the shards' results reproduces the full matrix run exactly.
        let tasks: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(2).collect();
        let strategies = vec![baselines::kernelskill(), baselines::wo_memory()];
        let cfg = LoopConfig::default();
        let full = run_matrix(&tasks, &strategies, &cfg, &[0, 1], 2);
        let shard0 = run_matrix_with(
            &tasks,
            &strategies,
            &cfg,
            &[0, 1],
            2,
            &SuiteOptions::default().with_shard(0, 2),
        )
        .unwrap();
        let shard1 = run_matrix_with(
            &tasks,
            &strategies,
            &cfg,
            &[0, 1],
            2,
            &SuiteOptions::default().with_shard(1, 2),
        )
        .unwrap();
        for ((f, a), b) in full.iter().zip(&shard0).zip(&shard1) {
            assert_eq!(f.strategy, a.strategy);
            assert_eq!(f.results.len(), a.results.len() + b.results.len());
            // Round-robin: shard 0 owns even flat indices, shard 1 odd.
            let mut union: Vec<&super::TaskResult> = Vec::new();
            let (mut ia, mut ib) = (a.results.iter(), b.results.iter());
            for ci in 0..f.results.len() {
                union.push(if ci % 2 == 0 { ia.next().unwrap() } else { ib.next().unwrap() });
            }
            for (x, y) in f.results.iter().zip(union) {
                assert_eq!(x.task_id, y.task_id);
                assert_eq!(x.best_speedup, y.best_speedup, "{}", x.task_id);
            }
        }
    }

    #[test]
    fn matrix_shares_a_run_dir_across_strategies() {
        let dir = std::env::temp_dir().join(format!("ks-matrix-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let tasks: Vec<_> = bench_suite::level_suite(42, 1).into_iter().take(2).collect();
        let strategies = vec![baselines::kernelskill(), baselines::wo_memory()];
        let cfg = LoopConfig::default();
        let opts = SuiteOptions::in_dir(&dir);
        let live = run_matrix_with(&tasks, &strategies, &cfg, &[0], 2, &opts).unwrap();
        // A full resume restores every cell without recomputing.
        let opts = SuiteOptions::resumed(&dir);
        let restored = run_matrix_with(&tasks, &strategies, &cfg, &[0], 2, &opts).unwrap();
        assert_eq!(live.len(), restored.len());
        for (a, b) in live.iter().zip(&restored) {
            assert_eq!(a.strategy, b.strategy);
            for (x, y) in a.results.iter().zip(&b.results) {
                assert_eq!(x.best_speedup, y.best_speedup);
                assert_eq!(x.rounds, y.rounds);
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
