//! The typed job protocol: [`JobSpec`] — the *single* definition of a
//! run's matrix identity — plus the newline-framed JSON messages the
//! `serve` daemon and the `jobs` CLI exchange over localhost TCP.
//!
//! Before this layer, run identity lived in a hand-maintained passthrough
//! string array in `main.rs`: `launch`/`worker` replayed individual CLI
//! flags to shard children (with `--flag=1` spellings to dodge the
//! parser's positional-swallow ambiguity), and the daemon path would have
//! had to replay them a third time. A `JobSpec` is parsed *once* — from
//! human CLI flags or from a canonical `--job-spec <file|json>` argument —
//! validated up front, and executed through one shared entry point, so the
//! batch path, the fan-out path, and the service path cannot drift.
//!
//! Serialization is canonical: objects serialize with sorted keys
//! (`util::json` is `BTreeMap`-backed), `u64` seeds ride as strings (the
//! run-manifest idiom — exact at any magnitude), optional fields are
//! omitted when absent, and the chaos spec is stored in its canonical
//! [`ChaosConfig::render`] form. Equal specs serialize to equal bytes.
//! Parsing is strict: an unknown field or a foreign `version` is refused
//! loudly (version skew must never silently drop part of a job's
//! identity), as is any value that fails the same validation the CLI
//! performs (unknown strategy/device/command, malformed chaos, zero
//! seeds).

use std::path::Path;

use crate::device::faults::ChaosConfig;
use crate::device::machine::DeviceSpec;
use crate::util::cli::Args;
use crate::util::json::{self, Json};

/// The job-spec wire/file format version this binary speaks.
pub const JOBSPEC_VERSION: u64 = 1;

/// Subcommands a `launch` / `worker` fleet may fan out (they must accept
/// `--run-dir/--shards/--shard-index/--resume`, and in elastic fleets
/// `--batch-index/--batch-count`).
pub const SHARDABLE: [&str; 5] = ["suite", "table1", "table2", "table3", "per-round"];

/// Every matrix-running subcommand a [`JobSpec`] may name: the shardable
/// set plus `trajectory` (which renders figures from the same matrix
/// machinery but is never fanned out).
pub const MATRIX_COMMANDS: [&str; 6] =
    ["suite", "table1", "table2", "table3", "per-round", "trajectory"];

/// The matrix-identity flags [`JobSpec::from_args`] reads — and therefore
/// refuses next to an explicit `--job-spec` (the spec *is* the identity;
/// a flag alongside it would silently lose).
const IDENTITY_FLAGS: [&str; 8] =
    ["strategy", "level", "take", "seeds", "suite-seed", "workers", "device", "chaos"];

/// A run's complete matrix identity: which command over which (strategy,
/// task, seed) matrix, priced on which device, under which faults.
/// Placement (`--run-dir`, `--shards/--shard-index`, `--batch-*`,
/// `--exchange-dir/--exchange-epoch`, `--resume`, `--memory-dir`) is
/// deliberately *not* here — invariant 12 makes output independent of
/// placement, so placement stays a per-process CLI concern.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobSpec {
    /// The matrix command (one of [`MATRIX_COMMANDS`]).
    pub cmd: String,
    /// Strategy name (`suite` only; table commands run their roster).
    pub strategy: String,
    /// Task level filter (`suite` only); 0 = the full suite.
    pub level: usize,
    /// Deterministic prefix slice of the task list; 0 = all tasks.
    pub take: usize,
    /// Number of run seeds (the matrix runs seeds `0..seeds`).
    pub seeds: usize,
    /// Suite-generation seed (task population).
    pub suite_seed: u64,
    /// Worker-pool size; 0 = this machine's default.
    pub workers: usize,
    /// Device preset name; `None` = the default (A100-like).
    pub device: Option<String>,
    /// Canonical chaos spec ([`ChaosConfig::render`] form); `None` = clean.
    pub chaos: Option<String>,
    /// Per-task-run retrieval memoization (off only for A/B timing).
    pub retrieval_cache: bool,
    /// Adaptive (doubling) exchange-epoch schedule.
    pub exchange_adaptive: bool,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            cmd: "suite".to_string(),
            strategy: "KernelSkill".to_string(),
            level: 0,
            take: 0,
            seeds: 1,
            suite_seed: 42,
            workers: 0,
            device: None,
            chaos: None,
            retrieval_cache: true,
            exchange_adaptive: false,
        }
    }
}

impl JobSpec {
    /// Build the spec for one invocation of `cmd`: from `--job-spec
    /// <file|json>` when given (refusing any identity flag alongside it),
    /// from the legacy human flags otherwise. Either way the result is
    /// validated and canonicalized — this is the one place run identity
    /// enters the system.
    pub fn from_args(cmd: &str, args: &Args) -> Result<JobSpec, String> {
        if let Some(v) = args.get("job-spec") {
            for flag in IDENTITY_FLAGS {
                if args.get(flag).is_some() {
                    return Err(format!(
                        "--{flag} conflicts with --job-spec: the spec is the whole matrix \
                         identity; edit the spec instead"
                    ));
                }
            }
            for switch in ["no-retrieval-cache", "exchange-adaptive"] {
                if args.has(switch) {
                    return Err(format!(
                        "--{switch} conflicts with --job-spec: the spec is the whole matrix \
                         identity; edit the spec instead"
                    ));
                }
            }
            let spec = if v.trim_start().starts_with('{') {
                JobSpec::parse(v)?
            } else {
                JobSpec::load(Path::new(v))?
            };
            if spec.cmd != cmd {
                return Err(format!(
                    "job spec names cmd {:?} but this invocation runs {cmd:?}; \
                     pass the spec to its own subcommand",
                    spec.cmd
                ));
            }
            return Ok(spec);
        }
        let defaults = JobSpec::default();
        let spec = JobSpec {
            cmd: cmd.to_string(),
            strategy: args.get_or("strategy", &defaults.strategy).to_string(),
            level: args.get_usize("level", defaults.level)?,
            take: args.get_usize("take", defaults.take)?,
            seeds: args.get_usize("seeds", defaults.seeds)?,
            suite_seed: args.get_u64("suite-seed", defaults.suite_seed)?,
            workers: args.get_usize("workers", defaults.workers)?,
            device: args.get("device").map(str::to_string),
            chaos: args.get("chaos").map(str::to_string),
            retrieval_cache: !args.has("no-retrieval-cache"),
            exchange_adaptive: args.has("exchange-adaptive"),
        };
        spec.normalized()
    }

    /// Validate every field against the same checks the CLI performs and
    /// canonicalize the chaos spec. Errors name the offending field.
    pub fn normalized(mut self) -> Result<JobSpec, String> {
        if !MATRIX_COMMANDS.contains(&self.cmd.as_str()) {
            return Err(format!(
                "job spec cmd {:?} is not a matrix command; expected one of {MATRIX_COMMANDS:?}",
                self.cmd
            ));
        }
        if crate::baselines::by_name(&self.strategy).is_none() {
            return Err(format!("job spec names unknown strategy {:?}", self.strategy));
        }
        if self.seeds == 0 {
            return Err("job spec seeds must be >= 1".to_string());
        }
        if let Some(name) = &self.device {
            if DeviceSpec::by_name(name).is_none() {
                return Err(format!(
                    "job spec names unknown device preset {name:?} (known: {:?})",
                    DeviceSpec::presets().iter().map(|p| p.name).collect::<Vec<_>>()
                ));
            }
        }
        if let Some(spec) = &self.chaos {
            self.chaos = Some(ChaosConfig::parse(spec)?.render());
        }
        Ok(self)
    }

    /// The validated device preset, when one is named.
    pub fn device_spec(&self) -> Option<DeviceSpec> {
        self.device.as_deref().and_then(DeviceSpec::by_name)
    }

    /// The validated chaos config, when one is set.
    pub fn chaos_config(&self) -> Result<Option<ChaosConfig>, String> {
        self.chaos.as_deref().map(ChaosConfig::parse).transpose()
    }

    /// Serialize to the canonical JSON form (sorted keys; `suite_seed` as
    /// a string for `u64` exactness; optional fields omitted when absent).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("cmd", json::s(&self.cmd)),
            ("exchange_adaptive", Json::Bool(self.exchange_adaptive)),
            ("level", json::num(self.level as f64)),
            ("retrieval_cache", Json::Bool(self.retrieval_cache)),
            ("seeds", json::num(self.seeds as f64)),
            ("strategy", json::s(&self.strategy)),
            ("suite_seed", json::s(&self.suite_seed.to_string())),
            ("take", json::num(self.take as f64)),
            ("version", json::num(JOBSPEC_VERSION as f64)),
            ("workers", json::num(self.workers as f64)),
        ];
        if let Some(d) = &self.device {
            pairs.push(("device", json::s(d)));
        }
        if let Some(c) = &self.chaos {
            pairs.push(("chaos", json::s(c)));
        }
        json::obj(pairs)
    }

    /// The exact bytes [`JobSpec::save`] writes: canonical JSON plus a
    /// trailing newline. Equal specs produce equal bytes.
    pub fn canonical_bytes(&self) -> Vec<u8> {
        format!("{}\n", self.to_json()).into_bytes()
    }

    /// Strict parse: a missing or foreign `version`, an unknown field, a
    /// wrong type, or a value the CLI would refuse is a loud error —
    /// version skew must never silently drop part of a job's identity.
    pub fn from_json(j: &Json) -> Result<JobSpec, String> {
        let obj = j.as_obj().ok_or("job spec is not a JSON object")?;
        const KNOWN: [&str; 12] = [
            "chaos", "cmd", "device", "exchange_adaptive", "level", "retrieval_cache",
            "seeds", "strategy", "suite_seed", "take", "version", "workers",
        ];
        for key in obj.keys() {
            if !KNOWN.contains(&key.as_str()) {
                return Err(format!(
                    "job spec field {key:?} is not part of job-spec version \
                     {JOBSPEC_VERSION} (version skew? this binary refuses rather than \
                     silently dropping it)"
                ));
            }
        }
        let version = j
            .get("version")
            .and_then(|v| v.as_f64())
            .ok_or("job spec missing version")? as u64;
        if version != JOBSPEC_VERSION {
            return Err(format!(
                "job spec version {version} but this binary speaks version {JOBSPEC_VERSION}"
            ));
        }
        let str_field = |k: &str| {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .ok_or_else(|| format!("job spec missing {k}"))
        };
        let num_field = |k: &str| {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| format!("job spec missing {k}"))
        };
        let bool_field = |k: &str| match j.get(k) {
            Some(Json::Bool(b)) => Ok(*b),
            _ => Err(format!("job spec missing {k}")),
        };
        let suite_seed = match j.get("suite_seed") {
            Some(Json::Str(s)) => s
                .parse::<u64>()
                .map_err(|e| format!("job spec suite_seed: {e}"))?,
            Some(Json::Num(n)) => *n as u64,
            _ => return Err("job spec missing suite_seed".to_string()),
        };
        let opt_str = |k: &str| -> Result<Option<String>, String> {
            match j.get(k) {
                None | Some(Json::Null) => Ok(None),
                Some(Json::Str(s)) => Ok(Some(s.clone())),
                Some(_) => Err(format!("job spec {k} must be a string")),
            }
        };
        let spec = JobSpec {
            cmd: str_field("cmd")?,
            strategy: str_field("strategy")?,
            level: num_field("level")?,
            take: num_field("take")?,
            seeds: num_field("seeds")?,
            suite_seed,
            workers: num_field("workers")?,
            device: opt_str("device")?,
            chaos: opt_str("chaos")?,
            retrieval_cache: bool_field("retrieval_cache")?,
            exchange_adaptive: bool_field("exchange_adaptive")?,
        };
        spec.normalized()
    }

    /// Parse a spec from JSON text.
    pub fn parse(text: &str) -> Result<JobSpec, String> {
        let j = Json::parse(text).map_err(|e| format!("parsing job spec: {e}"))?;
        JobSpec::from_json(&j)
    }

    /// Load a spec file.
    pub fn load(path: &Path) -> Result<JobSpec, String> {
        let bytes =
            std::fs::read(path).map_err(|e| format!("reading {}: {e}", path.display()))?;
        let text = std::str::from_utf8(&bytes)
            .map_err(|e| format!("{}: job spec is not UTF-8: {e}", path.display()))?;
        JobSpec::parse(text).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Atomic save (staging file + rename), the run-dir idiom.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| format!("creating {}: {e}", parent.display()))?;
            }
        }
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.canonical_bytes())
            .map_err(|e| format!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path).map_err(|e| format!("publishing {}: {e}", path.display()))
    }
}

// ------------------------------------------------------------------------
// Job lifecycle states
// ------------------------------------------------------------------------

/// Where a submitted job is in its lifecycle. `Done`, `Failed`, and
/// `Cancelled` are terminal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Accepted and durably queued, not yet claimed.
    Queued,
    /// Claimed by a scheduler; its child process is (or is being) run.
    Running,
    /// Finished successfully; its run dir carries the `complete` marker.
    Done,
    /// Crashed past its restart budget, or exceeded its deadline.
    Failed,
    /// Cancelled by a client before completion.
    Cancelled,
}

impl JobState {
    /// Canonical lowercase name (the wire and manifest spelling).
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// Parse the canonical spelling; anything else is refused loudly.
    pub fn parse(s: &str) -> Result<JobState, String> {
        match s {
            "queued" => Ok(JobState::Queued),
            "running" => Ok(JobState::Running),
            "done" => Ok(JobState::Done),
            "failed" => Ok(JobState::Failed),
            "cancelled" => Ok(JobState::Cancelled),
            other => Err(format!("unknown job state {other:?}")),
        }
    }

    /// No further transitions happen from this state.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

// ------------------------------------------------------------------------
// Wire messages (one JSON object per line, newline-framed, over localhost)
// ------------------------------------------------------------------------

/// A client request to the `serve` daemon.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Liveness probe (also how `jobs` waits for a daemon to come up).
    Ping,
    /// Submit a job; replies accepted (with the job id) or rejected with
    /// an explicit backpressure flag when the bounded queue is full.
    Submit {
        /// The job's matrix identity.
        spec: JobSpec,
        /// Optional wall-clock budget (milliseconds from job start); a
        /// running job past its deadline is killed and marked failed.
        deadline_ms: Option<u64>,
    },
    /// One job's current state.
    Status {
        /// Job id (`job-000001`).
        job: String,
    },
    /// Every job the service knows, in id order.
    List,
    /// Cancel a queued or running job.
    Cancel {
        /// Job id.
        job: String,
    },
    /// Stream progress events for one job until it reaches a terminal
    /// state (the connection stays open; one JSON event per line).
    Watch {
        /// Job id.
        job: String,
    },
    /// Stop accepting work and exit once the running job (if any)
    /// finishes. Queued jobs stay durably queued for the next daemon.
    Shutdown,
}

impl Request {
    /// Serialize to one wire line (no trailing newline).
    pub fn to_json(&self) -> Json {
        match self {
            Request::Ping => json::obj(vec![("op", json::s("ping"))]),
            Request::Submit { spec, deadline_ms } => {
                let mut pairs =
                    vec![("op", json::s("submit")), ("spec", spec.to_json())];
                if let Some(d) = deadline_ms {
                    pairs.push(("deadline_ms", json::s(&d.to_string())));
                }
                json::obj(pairs)
            }
            Request::Status { job } => {
                json::obj(vec![("job", json::s(job)), ("op", json::s("status"))])
            }
            Request::List => json::obj(vec![("op", json::s("list"))]),
            Request::Cancel { job } => {
                json::obj(vec![("job", json::s(job)), ("op", json::s("cancel"))])
            }
            Request::Watch { job } => {
                json::obj(vec![("job", json::s(job)), ("op", json::s("watch"))])
            }
            Request::Shutdown => json::obj(vec![("op", json::s("shutdown"))]),
        }
    }

    /// Parse one wire line. Unknown ops and malformed payloads are loud
    /// errors the daemon reports back to the client.
    pub fn parse(text: &str) -> Result<Request, String> {
        let j = Json::parse(text).map_err(|e| format!("request does not parse: {e}"))?;
        let op = j
            .get("op")
            .and_then(|v| v.as_str())
            .ok_or("request missing op")?;
        let job = |j: &Json| {
            j.get("job")
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .ok_or_else(|| format!("{op} request missing job"))
        };
        match op {
            "ping" => Ok(Request::Ping),
            "submit" => {
                let spec = j.get("spec").ok_or("submit request missing spec")?;
                let deadline_ms = match j.get("deadline_ms") {
                    None | Some(Json::Null) => None,
                    Some(Json::Str(s)) => {
                        Some(s.parse::<u64>().map_err(|e| format!("deadline_ms: {e}"))?)
                    }
                    Some(Json::Num(n)) => Some(*n as u64),
                    Some(_) => return Err("deadline_ms must be a number".to_string()),
                };
                Ok(Request::Submit {
                    spec: JobSpec::from_json(spec)?,
                    deadline_ms,
                })
            }
            "status" => Ok(Request::Status { job: job(&j)? }),
            "list" => Ok(Request::List),
            "cancel" => Ok(Request::Cancel { job: job(&j)? }),
            "watch" => Ok(Request::Watch { job: job(&j)? }),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(format!(
                "unknown op {other:?} (this daemon speaks ping/submit/status/list/\
                 cancel/watch/shutdown)"
            )),
        }
    }
}

/// Build a success response line with extra fields.
pub fn response_ok(fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("ok", Json::Bool(true))];
    pairs.extend(fields);
    json::obj(pairs)
}

/// Build an error response line. `backpressure` marks a bounded-queue
/// rejection — the one error a client is expected to retry later.
pub fn response_err(error: &str, backpressure: bool) -> Json {
    let mut pairs = vec![("error", json::s(error)), ("ok", Json::Bool(false))];
    if backpressure {
        pairs.push(("backpressure", Json::Bool(true)));
    }
    json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_roundtrips_byte_stable() {
        let spec = JobSpec::default();
        let bytes = spec.canonical_bytes();
        let back = JobSpec::parse(std::str::from_utf8(&bytes).unwrap()).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.canonical_bytes(), bytes);
    }

    #[test]
    fn optional_fields_roundtrip() {
        let spec = JobSpec {
            device: Some("tpu-like".to_string()),
            chaos: Some("tc=0.3,drop=0.05,sigma=0.2,bias=0.1,seed=7".to_string()),
            ..JobSpec::default()
        }
        .normalized()
        .unwrap();
        let back = JobSpec::parse(std::str::from_utf8(&spec.canonical_bytes()).unwrap()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn version_skew_and_unknown_fields_are_refused() {
        let mut j = JobSpec::default().to_json();
        if let Json::Obj(map) = &mut j {
            map.insert("version".to_string(), json::num(2.0));
        }
        let err = JobSpec::from_json(&j).unwrap_err();
        assert!(err.contains("version 2"), "{err}");

        let mut j = JobSpec::default().to_json();
        if let Json::Obj(map) = &mut j {
            map.insert("frobnicate".to_string(), json::num(1.0));
        }
        let err = JobSpec::from_json(&j).unwrap_err();
        assert!(err.contains("frobnicate"), "{err}");
    }

    #[test]
    fn invalid_values_are_refused() {
        for (mutate, needle) in [
            (("cmd", json::s("dance")), "matrix command"),
            (("strategy", json::s("Nope")), "unknown strategy"),
            (("seeds", json::num(0.0)), ">= 1"),
            (("device", json::s("abacus")), "device preset"),
            (("chaos", json::s("tc=zz")), "tc"),
        ] {
            let mut j = JobSpec::default().to_json();
            if let Json::Obj(map) = &mut j {
                map.insert(mutate.0.to_string(), mutate.1.clone());
            }
            let err = JobSpec::from_json(&j).unwrap_err();
            assert!(err.contains(needle), "{}: {err}", mutate.0);
        }
    }

    #[test]
    fn chaos_spec_is_canonicalized() {
        let spec = JobSpec {
            chaos: Some("seed=7,tc=0.30".to_string()),
            ..JobSpec::default()
        }
        .normalized()
        .unwrap();
        let canonical = ChaosConfig::parse("seed=7,tc=0.30").unwrap().render();
        assert_eq!(spec.chaos.as_deref(), Some(canonical.as_str()));
    }

    #[test]
    fn from_args_refuses_identity_flags_next_to_job_spec() {
        let args = Args::parse(
            ["suite", "--job-spec", "{}", "--seeds", "3"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let err = JobSpec::from_args("suite", &args).unwrap_err();
        assert!(err.contains("--seeds") && err.contains("--job-spec"), "{err}");
    }

    #[test]
    fn from_args_inline_spec_must_match_the_invoked_cmd() {
        let inline = String::from_utf8(
            JobSpec { cmd: "table1".into(), ..JobSpec::default() }.canonical_bytes(),
        )
        .unwrap();
        let args = Args::parse(
            ["suite", "--job-spec", inline.trim()].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let err = JobSpec::from_args("suite", &args).unwrap_err();
        assert!(err.contains("table1"), "{err}");
    }

    #[test]
    fn requests_roundtrip() {
        let reqs = vec![
            Request::Ping,
            Request::Submit { spec: JobSpec::default(), deadline_ms: Some(5000) },
            Request::Status { job: "job-000001".into() },
            Request::List,
            Request::Cancel { job: "job-000002".into() },
            Request::Watch { job: "job-000003".into() },
            Request::Shutdown,
        ];
        for r in reqs {
            let line = r.to_json().to_string();
            assert_eq!(Request::parse(&line).unwrap(), r, "{line}");
        }
        assert!(Request::parse(r#"{"op":"explode"}"#).is_err());
    }
}
