//! Merge per-shard run directories back into one — one-shot or streaming.
//!
//! A sharded suite run leaves N run dirs, each holding a disjoint slice of
//! the (strategy, task, seed) cell matrix (`Shard::owns`), a manifest, a
//! `results.jsonl` checkpoint, and a per-dir `skills.json` fold of its
//! cells' observations. [`merge_run_dirs`] unions them into an output run
//! dir that is indistinguishable from a single-process run:
//!
//!   * manifests are validated — every input must describe the same cell
//!     matrix (shard fields aside; the device preset may differ, which is
//!     how heterogeneous fleets merge — per-device evidence stays apart in
//!     the skill store's partitions and the output manifest records the
//!     sorted `+`-joined preset set); the output manifest is unsharded, so
//!     the merged dir can itself be `report`ed, `--resume`d (homogeneous
//!     inputs only), or merged again.
//!   * `results.jsonl` lines are unioned with torn tails tolerated and
//!     written in canonical key order, so merge output is
//!     byte-deterministic whatever order shards are given in.
//!   * duplicate cells are deduplicated when their payloads are
//!     bit-identical and a **loud error** otherwise — never
//!     last-writer-wins: two different results for one cell mean the
//!     shards disagree about the experiment, and silently picking one
//!     would corrupt the aggregates.
//!   * `skills.json` stores are folded with [`SkillStore::merge_store`],
//!     whose exact-sum gain totals and max-combined generation stamps make
//!     the fold commutative/associative at the bit level; the fold is
//!     cross-checked against a store rebuilt from the unioned cells'
//!     observations (a lagging shard store — the same crash class as a
//!     torn tail — is tolerated with a warning, and the cell-derived store
//!     is what gets written). Run-dir stores fold at epoch 1 over a cold
//!     base, so the rebuild lands on identical generation stamps whatever
//!     the partitioning was.
//!   * warm-start memory snapshots must agree byte-for-byte across shards
//!     (otherwise the shards did not run slices of one experiment — hard
//!     error) and are carried into the output for resumability.
//!
//! [`MergeWatcher`] is the *streaming* form of the same union: it follows
//! the per-shard `results.jsonl` tails while the shards are still running
//! (consuming only newline-terminated lines, so a mid-append read can
//! never tear a record), maintains the live folded cell set, and
//! [`MergeWatcher::finalize`]s into the output dir. One-shot
//! [`merge_run_dirs`] is implemented *as* a finalize-immediately watcher,
//! so the streaming result after every shard completes is byte-identical
//! to a one-shot merge by construction — and pinned by a test on top.
//!
//! The cross-machine launch (`coordinator::transport` +
//! `launch --manifest`) feeds this same watcher with *mirrors* of remote
//! shard run dirs. That works without any merge-side special casing
//! because the transports guarantee exactly the visibility this module
//! already assumes: whole files appear atomically, and checkpoint mirrors
//! only ever grow by newline-terminated lines — so to the watcher a
//! remote worker is indistinguishable from a local shard process.
//!
//! Net effect: `report` over the merged dir is byte-identical to `report`
//! over an unsharded run of the same matrix, and so is the skill store —
//! the property the determinism test battery (tests/sharding.rs and the CI
//! `shard-smoke` / `launch-smoke` jobs) pins down.

use std::collections::BTreeMap;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use super::checkpoint::{result_from_json, result_to_json, CellKey, RunDir, RunManifest};
use super::loop_runner::TaskResult;
use crate::memory::long_term::SkillStore;
use crate::util::json::Json;

/// What one input directory contributed.
#[derive(Debug, Clone)]
pub struct ShardSummary {
    /// The input run directory.
    pub dir: PathBuf,
    /// Shard index its manifest declared.
    pub shard_index: usize,
    /// Total shard count its manifest declared.
    pub shards: usize,
    /// Elastic lease batch index its manifest declared (0 when the input
    /// was not batch-sliced; check `lease_batches` to distinguish batch 0).
    pub lease_batch: usize,
    /// Elastic lease batch count its manifest declared (0 = not
    /// batch-sliced).
    pub lease_batches: usize,
    /// Parseable cells it contributed.
    pub cells: usize,
}

/// Outcome of a successful merge.
#[derive(Debug, Clone)]
pub struct MergeReport {
    /// Per-input contribution summaries.
    pub inputs: Vec<ShardSummary>,
    /// Distinct cells written to the output.
    pub merged_cells: usize,
    /// Duplicate lines dropped because they were bit-identical.
    pub deduplicated: usize,
    /// Observations in the merged (cell-derived) skill store.
    pub skill_observations: u64,
    /// Slice indices the inputs' manifests declare but no input covered:
    /// shard indices for range-sharded inputs, lease batch indices for
    /// elastic (batch-sliced) inputs. Non-empty means the output holds a
    /// partial matrix (merge-then-resume is supported, but the gap should
    /// never be silent).
    pub missing_shards: Vec<usize>,
}

impl MergeReport {
    /// Human-readable multi-line summary (the `merge` CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "merged {} run dir(s): {} cell(s), {} bit-identical duplicate(s) dropped\n",
            self.inputs.len(),
            self.merged_cells,
            self.deduplicated
        ));
        for s in &self.inputs {
            if s.lease_batches > 0 {
                out.push_str(&format!(
                    "  batch {}/{}  {:<40} {} cell(s)\n",
                    s.lease_batch,
                    s.lease_batches,
                    s.dir.display(),
                    s.cells
                ));
            } else {
                out.push_str(&format!(
                    "  shard {}/{}  {:<40} {} cell(s)\n",
                    s.shard_index,
                    s.shards,
                    s.dir.display(),
                    s.cells
                ));
            }
        }
        if !self.missing_shards.is_empty() {
            out.push_str(&format!(
                "WARNING: slice index(es) {:?} missing — the output covers a partial \
                 matrix; merge the missing dirs or --resume the output to finish it\n",
                self.missing_shards
            ));
        }
        out.push_str(&format!(
            "skill store: {} observation(s) merged\n",
            self.skill_observations
        ));
        out
    }
}

/// One streamed input of a [`MergeWatcher`].
#[derive(Debug)]
struct WatchInput {
    dir: PathBuf,
    /// Byte offset into `results.jsonl` already consumed (always at a line
    /// boundary until the final drain).
    offset: u64,
    /// Parseable cells folded from this input so far.
    cells: usize,
    /// Manifest, once it appeared on disk and validated.
    manifest: Option<RunManifest>,
    /// Whether the dir has been canonicalized and checked against the
    /// output dir (deferred until the dir exists — shards create their dirs
    /// after the watcher typically starts).
    checked_distinct: bool,
}

/// Live progress of a [`MergeWatcher`].
#[derive(Debug, Clone)]
pub struct WatchStatus {
    /// Distinct cells folded so far.
    pub cells: usize,
    /// Bit-identical duplicate lines dropped so far.
    pub deduplicated: usize,
    /// Parseable cells consumed per input, in input order.
    pub per_input: Vec<usize>,
    /// Per input: has the producing process written its `complete` marker?
    pub complete: Vec<bool>,
}

impl WatchStatus {
    /// True once every input carries the `complete` marker.
    pub fn all_complete(&self) -> bool {
        self.complete.iter().all(|&c| c)
    }

    /// One-line live summary for the `merge --watch` / `launch` CLIs.
    pub fn render(&self) -> String {
        let per: Vec<String> = self.per_input.iter().map(|c| c.to_string()).collect();
        format!(
            "{} cell(s) merged live [{}], {} duplicate(s), {}/{} input(s) complete",
            self.cells,
            per.join(" + "),
            self.deduplicated,
            self.complete.iter().filter(|&&c| c).count(),
            self.complete.len()
        )
    }
}

/// Incremental merge over still-growing shard run dirs. See the module docs
/// for the contract; construct with [`MergeWatcher::new`], drive with
/// [`MergeWatcher::poll`], and [`MergeWatcher::finalize`] once the
/// producers are done (all inputs `complete`, or their processes exited).
#[derive(Debug)]
pub struct MergeWatcher {
    out: PathBuf,
    out_canon: PathBuf,
    inputs: Vec<WatchInput>,
    base: Option<RunManifest>,
    first_dir: PathBuf,
    /// key -> (canonical serialized line, parsed result)
    merged: BTreeMap<CellKey, (String, TaskResult)>,
    deduplicated: usize,
}

impl MergeWatcher {
    /// Start watching `inputs` for an eventual merge into `out`. `out` is
    /// created immediately and must not already hold results; the inputs
    /// need not exist yet.
    pub fn new(out: &Path, inputs: &[PathBuf]) -> Result<MergeWatcher, String> {
        if inputs.is_empty() {
            return Err("merge needs at least one input run dir".to_string());
        }
        let out_rd =
            RunDir::open(out).map_err(|e| format!("opening output dir {}: {e}", out.display()))?;
        if out_rd.has_results() {
            return Err(format!(
                "output dir {} already holds results; merge refuses to overwrite",
                out.display()
            ));
        }
        let out_canon = std::fs::canonicalize(out)
            .map_err(|e| format!("resolving {}: {e}", out.display()))?;
        Ok(MergeWatcher {
            out: out.to_path_buf(),
            out_canon,
            inputs: inputs
                .iter()
                .map(|dir| WatchInput {
                    dir: dir.clone(),
                    offset: 0,
                    cells: 0,
                    manifest: None,
                    checked_distinct: false,
                })
                .collect(),
            base: None,
            first_dir: inputs[0].clone(),
            merged: BTreeMap::new(),
            deduplicated: 0,
        })
    }

    /// Start a watcher whose inputs are discovered *while it runs* — the
    /// elastic-fleet shape, where batch mirrors appear as leases are
    /// claimed. Finalizing with zero inputs is an error, matching
    /// [`MergeWatcher::new`]'s non-empty requirement.
    pub fn new_dynamic(out: &Path) -> Result<MergeWatcher, String> {
        let out_rd =
            RunDir::open(out).map_err(|e| format!("opening output dir {}: {e}", out.display()))?;
        if out_rd.has_results() {
            return Err(format!(
                "output dir {} already holds results; merge refuses to overwrite",
                out.display()
            ));
        }
        let out_canon = std::fs::canonicalize(out)
            .map_err(|e| format!("resolving {}: {e}", out.display()))?;
        Ok(MergeWatcher {
            out: out.to_path_buf(),
            out_canon,
            inputs: Vec::new(),
            base: None,
            first_dir: out.to_path_buf(),
            merged: BTreeMap::new(),
            deduplicated: 0,
        })
    }

    /// Add one more input directory to a running watcher (no-op if the
    /// path is already an input). The next [`MergeWatcher::poll`] starts
    /// consuming it from byte zero.
    pub fn add_input(&mut self, dir: &Path) {
        if self.inputs.iter().any(|i| i.dir == dir) {
            return;
        }
        if self.inputs.is_empty() {
            self.first_dir = dir.to_path_buf();
        }
        self.inputs.push(WatchInput {
            dir: dir.to_path_buf(),
            offset: 0,
            cells: 0,
            manifest: None,
            checked_distinct: false,
        });
    }

    /// Fold one parsed cell in, enforcing the dedup/conflict rules.
    fn fold_cell(
        merged: &mut BTreeMap<CellKey, (String, TaskResult)>,
        deduplicated: &mut usize,
        dir: &Path,
        key: CellKey,
        result: TaskResult,
    ) -> Result<(), String> {
        let line = result_to_json(&key, &result).to_string();
        match merged.get(&key) {
            None => {
                merged.insert(key, (line, result));
            }
            Some((existing, _)) if *existing == line => *deduplicated += 1,
            Some(_) => {
                return Err(format!(
                    "conflicting results for cell ({}, {}, {}): {} holds a payload \
                     that differs from an earlier input; refusing to merge \
                     (same cell, different outcome means the shards did not run \
                     the same experiment)",
                    key.strategy,
                    key.task_id,
                    key.seed,
                    dir.display()
                ));
            }
        }
        Ok(())
    }

    /// Validate a newly appeared manifest against the first one seen.
    /// Compatibility is [`RunManifest::same_matrix_modulo_device`]: slices
    /// of one experiment may legitimately differ in device preset (a
    /// heterogeneous fleet), because their evidence stays separated by the
    /// skill store's per-device partitions and their cells are disjoint —
    /// any *overlapping* cells from different devices still collide in
    /// `fold_cell`'s payload-conflict check and fail loudly. Every other
    /// identity field must match exactly.
    fn fold_manifest(&mut self, i: usize, manifest: RunManifest) -> Result<(), String> {
        match &self.base {
            None => self.base = Some(manifest.clone()),
            Some(b) if !b.same_matrix_modulo_device(&manifest) => {
                return Err(format!(
                    "{} was written for a different cell matrix than {} \
                     ({manifest:?} vs {b:?}); refusing to mix results",
                    self.inputs[i].dir.display(),
                    self.first_dir.display()
                ));
            }
            Some(_) => {}
        }
        self.inputs[i].manifest = Some(manifest);
        Ok(())
    }

    /// Consume one input's new bytes. Only newline-terminated lines are
    /// taken (a concurrent append can tear at most the unterminated tail,
    /// which stays unconsumed until the next poll); with `drain_tail` the
    /// final unterminated fragment is attempted too — exactly what a
    /// one-shot loader would do after the producer is gone.
    fn poll_input(&mut self, i: usize, drain_tail: bool) -> Result<(), String> {
        let dir = self.inputs[i].dir.clone();
        if !dir.exists() {
            // The shard has not created its run dir yet (streaming) or the
            // path is wrong (one-shot) — finalize reports the latter as a
            // missing manifest.
            return Ok(());
        }
        if !self.inputs[i].checked_distinct {
            let canon = std::fs::canonicalize(&dir)
                .map_err(|e| format!("resolving {}: {e}", dir.display()))?;
            if canon == self.out_canon {
                return Err(format!(
                    "output dir {} is also a merge input; pick a fresh output directory",
                    self.out.display()
                ));
            }
            self.inputs[i].checked_distinct = true;
        }
        let rd = match RunDir::open(&dir) {
            Ok(rd) => rd,
            Err(e) => return Err(format!("opening {}: {e}", dir.display())),
        };
        if self.inputs[i].manifest.is_none() && rd.manifest_path().exists() {
            if let Some(m) = rd.read_manifest()? {
                self.fold_manifest(i, m)?;
            }
        }

        let path = rd.results_path();
        if !path.exists() {
            return Ok(());
        }
        let mut f = std::fs::File::open(&path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let len = f
            .metadata()
            .map_err(|e| format!("reading {}: {e}", path.display()))?
            .len();
        let offset = self.inputs[i].offset;
        if len <= offset {
            return Ok(());
        }
        f.seek(SeekFrom::Start(offset))
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let mut buf = Vec::with_capacity((len - offset) as usize);
        f.read_to_end(&mut buf)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        // Consume up to the last newline; the remainder may still be
        // mid-append. The final drain takes the unterminated fragment too —
        // the same attempt a one-shot loader makes once the producer is
        // gone.
        let advanced = if drain_tail {
            buf.len()
        } else {
            buf.iter().rposition(|&b| b == b'\n').map_or(0, |p| p + 1)
        };
        let chunk = &buf[..advanced];
        for line in chunk.split(|&b| b == b'\n') {
            let text = match std::str::from_utf8(line) {
                Ok(t) => t,
                Err(e) => {
                    crate::log_warn!(
                        "checkpoint {}: skipping undecodable line ({e})",
                        path.display()
                    );
                    continue;
                }
            };
            if text.trim().is_empty() {
                continue;
            }
            let parsed = Json::parse(text)
                .map_err(|e| e.to_string())
                .and_then(|j| result_from_json(&j));
            match parsed {
                Ok((key, result)) => {
                    self.inputs[i].cells += 1;
                    Self::fold_cell(
                        &mut self.merged,
                        &mut self.deduplicated,
                        &dir,
                        key,
                        result,
                    )?;
                }
                Err(e) => {
                    crate::log_warn!(
                        "checkpoint {}: skipping unparseable line ({e})",
                        path.display()
                    );
                }
            }
        }
        self.inputs[i].offset = offset + advanced as u64;
        Ok(())
    }

    /// Fold every input's newly appended complete lines and report live
    /// progress. Safe to call while the shards are still appending; errors
    /// (conflicting cells, mismatched manifests) are permanent.
    pub fn poll(&mut self) -> Result<WatchStatus, String> {
        for i in 0..self.inputs.len() {
            self.poll_input(i, false)?;
        }
        Ok(self.status())
    }

    /// Current progress without reading anything new. A plain path probe —
    /// never `RunDir::open`, which would *create* a missing (e.g. typo'd)
    /// input directory as a side effect of polling.
    pub fn status(&self) -> WatchStatus {
        WatchStatus {
            cells: self.merged.len(),
            deduplicated: self.deduplicated,
            per_input: self.inputs.iter().map(|s| s.cells).collect(),
            complete: self
                .inputs
                .iter()
                .map(|s| s.dir.join(RunDir::COMPLETE_MARKER).exists())
                .collect(),
        }
    }

    /// Drain every remaining byte (unterminated tails included), validate
    /// manifests/snapshots/stores, and write the merged output dir. The
    /// result is byte-identical to a one-shot [`merge_run_dirs`] over the
    /// same final inputs.
    pub fn finalize(mut self) -> Result<MergeReport, String> {
        for i in 0..self.inputs.len() {
            self.poll_input(i, true)?;
        }

        // Every input must have turned out to be a run directory.
        let mut summaries: Vec<ShardSummary> = Vec::new();
        for input in &self.inputs {
            let manifest = input.manifest.as_ref().ok_or_else(|| {
                format!(
                    "{}: no manifest.json — not a run directory",
                    input.dir.display()
                )
            })?;
            summaries.push(ShardSummary {
                dir: input.dir.clone(),
                shard_index: manifest.shard_index,
                shards: manifest.shards,
                lease_batch: manifest.lease_batch,
                lease_batches: manifest.lease_batches,
                cells: input.cells,
            });
        }
        let base = match self.base {
            Some(b) => b,
            // Unreachable in practice (inputs is non-empty and each input
            // above proved it has a manifest), but a missing base must be a
            // clean error, never a panic that takes a fleet down.
            None => return Err("merge needs at least one input run dir".to_string()),
        };

        // Per-shard skills.json stores, folded commutatively. None once any
        // input lacks one (pre-sharding dirs) — then only the cell-derived
        // store below is available.
        let mut folded_stores: Option<SkillStore> = Some(SkillStore::new());
        // Warm-start snapshots (memory_snapshot.<strategy>.json): cells of a
        // sharded warm run are only equivalent to a single-process run if
        // every shard started from the same snapshot, so inputs must carry
        // the same snapshot set with identical bytes — a warm shard merged
        // with a cold one (or with different warm stores) is a hard error.
        // Identical snapshots are carried into the output so the merged dir
        // stays resumable with identical warm-started retrieval.
        let mut snapshots: BTreeMap<String, Vec<u8>> = BTreeMap::new();
        let mut snapshot_names_of_first: Option<Vec<String>> = None;
        for input in &self.inputs {
            let dir = &input.dir;
            let rd = RunDir::open(dir).map_err(|e| format!("opening {}: {e}", dir.display()))?;
            let sp = rd.skills_path();
            if sp.exists() {
                if let Some(fold) = folded_stores.as_mut() {
                    fold.merge_store(&SkillStore::load(&sp)?);
                }
            } else {
                folded_stores = None;
            }

            let mut names: Vec<String> = Vec::new();
            for entry in
                std::fs::read_dir(dir).map_err(|e| format!("listing {}: {e}", dir.display()))?
            {
                let entry = entry.map_err(|e| format!("listing {}: {e}", dir.display()))?;
                let name = entry.file_name().to_string_lossy().into_owned();
                if !(name.starts_with("memory_snapshot.") && name.ends_with(".json")) {
                    continue;
                }
                let bytes = std::fs::read(entry.path())
                    .map_err(|e| format!("reading {}: {e}", entry.path().display()))?;
                names.push(name.clone());
                match snapshots.get(&name) {
                    None => {
                        snapshots.insert(name, bytes);
                    }
                    Some(prev) if *prev == bytes => {}
                    Some(_) => {
                        return Err(format!(
                            "{}: {name} differs between shards — the shards warm-started \
                             from different skill stores, so their cells are not slices of \
                             one experiment; refusing to merge",
                            dir.display()
                        ));
                    }
                }
            }
            names.sort();
            match &snapshot_names_of_first {
                None => snapshot_names_of_first = Some(names),
                Some(first) if *first == names => {}
                Some(_) => {
                    return Err(format!(
                        "{}: warm-start snapshot set differs from {} — a warm shard \
                         cannot be merged with a cold one (their cells did not see the \
                         same memory); refusing to merge",
                        dir.display(),
                        self.first_dir.display()
                    ));
                }
            }
        }

        // The authoritative merged store: cold fold of the unioned cells'
        // observations (exact sums make the order irrelevant). Deduplicated
        // cells contribute once, which is why this — not the per-shard fold
        // — is what gets written.
        let store = SkillStore::from_observations(
            self.merged
                .values()
                .flat_map(|(_, result)| result.skill_obs.iter()),
        );
        // Cross-check: with disjoint shards (nothing deduplicated), folding
        // the per-shard stores reproduces the cell-derived store bit for
        // bit. A mismatch is the same crash class as a torn tail — a shard
        // killed between a results append and its store save lags by one
        // cell — so it is tolerated with a warning; the cell-derived store
        // is authoritative either way (resuming the shard also reconciles
        // its store).
        if self.deduplicated == 0 {
            if let Some(fold) = &folded_stores {
                if *fold != store {
                    crate::log_warn!(
                        "per-shard skills.json stores lag their checkpoints (interrupted \
                         shard?); using the store rebuilt from the checkpointed cells"
                    );
                }
            }
        }

        // Write the output dir: unsharded manifest, canonically-ordered
        // results.jsonl (atomic via tmp + rename), merged skill store.
        let out_rd = RunDir::open(&self.out)
            .map_err(|e| format!("opening output dir {}: {e}", self.out.display()))?;
        let mut manifest = base;
        // Placement is erased from the output: it is a whole (or partial)
        // matrix now, not a shard or a lease batch of one. Experiment
        // identity (exchange_epoch, exchange_adaptive, chaos, …) is kept.
        manifest.shards = 1;
        manifest.shard_index = 0;
        manifest.lease_batches = 0;
        manifest.lease_batch = 0;
        // Device: the sorted join of every input's preset. Homogeneous
        // merges keep the single name (byte-identical to the pre-relaxation
        // output); a heterogeneous fleet records e.g. "a100-like+tpu-like",
        // which deliberately matches no single preset — the merged dir can
        // be reported and re-merged, but not resumed under one device.
        let mut devices: Vec<&str> = self
            .inputs
            .iter()
            .filter_map(|inp| inp.manifest.as_ref())
            .flat_map(|m| m.device.split('+'))
            .collect();
        devices.sort_unstable();
        devices.dedup();
        manifest.device = devices.join("+");
        out_rd
            .write_manifest(&manifest)
            .map_err(|e| format!("writing merged manifest: {e}"))?;
        let mut buf = String::new();
        for (line, _) in self.merged.values() {
            buf.push_str(line);
            buf.push('\n');
        }
        let results_path = out_rd.results_path();
        let tmp = results_path.with_extension("jsonl.tmp");
        std::fs::write(&tmp, buf).map_err(|e| format!("writing merged results: {e}"))?;
        std::fs::rename(&tmp, &results_path)
            .map_err(|e| format!("writing merged results: {e}"))?;
        store
            .save(&out_rd.skills_path())
            .map_err(|e| format!("writing merged skill store: {e}"))?;
        for (name, bytes) in &snapshots {
            std::fs::write(out_rd.root().join(name), bytes)
                .map_err(|e| format!("writing merged snapshot {name}: {e}"))?;
        }

        // Coverage check: the manifests declare how many shards the matrix
        // was split into; missing indices mean a partial merge. Supported
        // (the output can be --resume'd to completion), but never silent.
        let batch_mode = summaries.iter().any(|s| s.lease_batches > 0);
        let (declared, missing_shards) = if batch_mode {
            // Elastic inputs: coverage is counted in lease batches, not
            // shard ranges (elastic manifests carry placeholder ranges).
            let declared = summaries.iter().map(|s| s.lease_batches).max().unwrap_or(1);
            let missing: Vec<usize> = (0..declared)
                .filter(|k| {
                    !summaries.iter().any(|s| s.lease_batches > 0 && s.lease_batch == *k)
                })
                .collect();
            (declared, missing)
        } else {
            let declared = summaries.iter().map(|s| s.shards).max().unwrap_or(1);
            let missing: Vec<usize> = (0..declared)
                .filter(|i| !summaries.iter().any(|s| s.shard_index == *i))
                .collect();
            (declared, missing)
        };
        if !missing_shards.is_empty() {
            crate::log_warn!(
                "merged {} input(s) but the manifests declare {declared} slice(s); \
                 missing slice index(es) {missing_shards:?} — the output covers a \
                 partial matrix",
                summaries.len()
            );
        }

        Ok(MergeReport {
            inputs: summaries,
            merged_cells: self.merged.len(),
            deduplicated: self.deduplicated,
            skill_observations: store.observations,
            missing_shards,
        })
    }
}

/// Union per-shard run dirs into `out` in one shot. See the module docs for
/// the rules. Implemented as a [`MergeWatcher`] that finalizes immediately,
/// so one-shot and streaming merges share every validation and every output
/// byte.
pub fn merge_run_dirs(out: &Path, inputs: &[PathBuf]) -> Result<MergeReport, String> {
    MergeWatcher::new(out, inputs)?.finalize()
}
