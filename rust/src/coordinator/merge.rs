//! Merge per-shard run directories back into one.
//!
//! A sharded suite run leaves N run dirs, each holding a disjoint slice of
//! the (strategy, task, seed) cell matrix (`Shard::owns`), a manifest, a
//! `results.jsonl` checkpoint, and a per-dir `skills.json` fold of its
//! cells' observations. [`merge_run_dirs`] unions them into an output run
//! dir that is indistinguishable from a single-process run:
//!
//!   * manifests are validated — every input must describe the same cell
//!     matrix (shard fields aside); the output manifest is unsharded, so
//!     the merged dir can itself be `report`ed, `--resume`d, or merged
//!     again.
//!   * `results.jsonl` lines are unioned with torn tails tolerated
//!     (`RunDir::load_all`) and written in canonical key order, so merge
//!     output is byte-deterministic whatever order shards are given in.
//!   * duplicate cells are deduplicated when their payloads are
//!     bit-identical and a **loud error** otherwise — never
//!     last-writer-wins: two different results for one cell mean the
//!     shards disagree about the experiment, and silently picking one
//!     would corrupt the aggregates.
//!   * `skills.json` stores are folded with [`SkillStore::merge_store`],
//!     whose exact-sum gain totals and max-combined generation stamps make
//!     the fold commutative/associative at the bit level; the fold is
//!     cross-checked against a store rebuilt from the unioned cells'
//!     observations (a lagging shard store — the same crash class as a
//!     torn tail — is tolerated with a warning, and the cell-derived store
//!     is what gets written). Run-dir stores fold at epoch 1 over a cold
//!     base, so the rebuild lands on identical generation stamps whatever
//!     the partitioning was.
//!   * warm-start memory snapshots must agree byte-for-byte across shards
//!     (otherwise the shards did not run slices of one experiment — hard
//!     error) and are carried into the output for resumability.
//!
//! Net effect: `report` over the merged dir is byte-identical to `report`
//! over an unsharded run of the same matrix, and so is the skill store —
//! the property the determinism test battery (tests/sharding.rs and the CI
//! `shard-smoke` job) pins down.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::checkpoint::{result_to_json, CellKey, RunDir, RunManifest};
use super::loop_runner::TaskResult;
use crate::memory::long_term::SkillStore;

/// What one input directory contributed.
#[derive(Debug, Clone)]
pub struct ShardSummary {
    /// The input run directory.
    pub dir: PathBuf,
    /// Shard index its manifest declared.
    pub shard_index: usize,
    /// Total shard count its manifest declared.
    pub shards: usize,
    /// Parseable cells it contributed.
    pub cells: usize,
}

/// Outcome of a successful merge.
#[derive(Debug, Clone)]
pub struct MergeReport {
    /// Per-input contribution summaries.
    pub inputs: Vec<ShardSummary>,
    /// Distinct cells written to the output.
    pub merged_cells: usize,
    /// Duplicate lines dropped because they were bit-identical.
    pub deduplicated: usize,
    /// Observations in the merged (cell-derived) skill store.
    pub skill_observations: u64,
    /// Shard indices the inputs' manifests declare but no input covered.
    /// Non-empty means the output holds a partial matrix (merge-then-resume
    /// is supported, but the gap should never be silent).
    pub missing_shards: Vec<usize>,
}

impl MergeReport {
    /// Human-readable multi-line summary (the `merge` CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "merged {} run dir(s): {} cell(s), {} bit-identical duplicate(s) dropped\n",
            self.inputs.len(),
            self.merged_cells,
            self.deduplicated
        ));
        for s in &self.inputs {
            out.push_str(&format!(
                "  shard {}/{}  {:<40} {} cell(s)\n",
                s.shard_index,
                s.shards,
                s.dir.display(),
                s.cells
            ));
        }
        if !self.missing_shards.is_empty() {
            out.push_str(&format!(
                "WARNING: shard index(es) {:?} missing — the output covers a partial \
                 matrix; merge the missing dirs or --resume the output to finish it\n",
                self.missing_shards
            ));
        }
        out.push_str(&format!(
            "skill store: {} observation(s) merged\n",
            self.skill_observations
        ));
        out
    }
}

/// Union per-shard run dirs into `out`. See the module docs for the rules.
pub fn merge_run_dirs(out: &Path, inputs: &[PathBuf]) -> Result<MergeReport, String> {
    if inputs.is_empty() {
        return Err("merge needs at least one input run dir".to_string());
    }
    let out_rd = RunDir::open(out).map_err(|e| format!("opening output dir {}: {e}", out.display()))?;
    if out_rd.has_results() {
        return Err(format!(
            "output dir {} already holds results; merge refuses to overwrite",
            out.display()
        ));
    }
    let out_canon = std::fs::canonicalize(out).map_err(|e| format!("resolving {}: {e}", out.display()))?;

    let mut base: Option<RunManifest> = None;
    // key -> (canonical serialized line, parsed result)
    let mut merged: BTreeMap<CellKey, (String, TaskResult)> = BTreeMap::new();
    let mut deduplicated = 0usize;
    let mut summaries: Vec<ShardSummary> = Vec::new();
    // Per-shard skills.json stores, folded commutatively. None once any
    // input lacks one (pre-sharding dirs) — then only the cell-derived
    // store below is available.
    let mut folded_stores: Option<SkillStore> = Some(SkillStore::new());
    // Warm-start snapshots (memory_snapshot.<strategy>.json): cells of a
    // sharded warm run are only equivalent to a single-process run if every
    // shard started from the same snapshot, so inputs must carry the same
    // snapshot set with identical bytes — a warm shard merged with a cold
    // one (or with different warm stores) is a hard error. Identical
    // snapshots are carried into the output so the merged dir stays
    // resumable with identical warm-started retrieval.
    let mut snapshots: BTreeMap<String, Vec<u8>> = BTreeMap::new();
    let mut snapshot_names_of_first: Option<Vec<String>> = None;

    for dir in inputs {
        let canon = std::fs::canonicalize(dir).map_err(|e| format!("resolving {}: {e}", dir.display()))?;
        if canon == out_canon {
            return Err(format!(
                "output dir {} is also a merge input; pick a fresh output directory",
                out.display()
            ));
        }
        let rd = RunDir::open(dir).map_err(|e| format!("opening {}: {e}", dir.display()))?;
        let manifest = rd
            .read_manifest()?
            .ok_or_else(|| format!("{}: no manifest.json — not a run directory", dir.display()))?;
        match &base {
            None => base = Some(manifest.clone()),
            Some(b) if !b.same_matrix(&manifest) => {
                return Err(format!(
                    "{} was written for a different cell matrix than {} \
                     ({manifest:?} vs {b:?}); refusing to mix results",
                    dir.display(),
                    inputs[0].display()
                ));
            }
            Some(_) => {}
        }

        let cells = rd
            .load_all()
            .map_err(|e| format!("loading {}: {e}", dir.display()))?;
        let mut count = 0usize;
        for (key, result) in cells {
            count += 1;
            let line = result_to_json(&key, &result).to_string();
            match merged.get(&key) {
                None => {
                    merged.insert(key, (line, result));
                }
                Some((existing, _)) if *existing == line => deduplicated += 1,
                Some(_) => {
                    return Err(format!(
                        "conflicting results for cell ({}, {}, {}): {} holds a payload \
                         that differs from an earlier input; refusing to merge \
                         (same cell, different outcome means the shards did not run \
                         the same experiment)",
                        key.strategy,
                        key.task_id,
                        key.seed,
                        dir.display()
                    ));
                }
            }
        }
        summaries.push(ShardSummary {
            dir: dir.clone(),
            shard_index: manifest.shard_index,
            shards: manifest.shards,
            cells: count,
        });

        let sp = rd.skills_path();
        if sp.exists() {
            if let Some(fold) = folded_stores.as_mut() {
                fold.merge_store(&SkillStore::load(&sp)?);
            }
        } else {
            folded_stores = None;
        }

        let mut names: Vec<String> = Vec::new();
        for entry in std::fs::read_dir(dir).map_err(|e| format!("listing {}: {e}", dir.display()))? {
            let entry = entry.map_err(|e| format!("listing {}: {e}", dir.display()))?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if !(name.starts_with("memory_snapshot.") && name.ends_with(".json")) {
                continue;
            }
            let bytes = std::fs::read(entry.path())
                .map_err(|e| format!("reading {}: {e}", entry.path().display()))?;
            names.push(name.clone());
            match snapshots.get(&name) {
                None => {
                    snapshots.insert(name, bytes);
                }
                Some(prev) if *prev == bytes => {}
                Some(_) => {
                    return Err(format!(
                        "{}: {name} differs between shards — the shards warm-started \
                         from different skill stores, so their cells are not slices of \
                         one experiment; refusing to merge",
                        dir.display()
                    ));
                }
            }
        }
        names.sort();
        match &snapshot_names_of_first {
            None => snapshot_names_of_first = Some(names),
            Some(first) if *first == names => {}
            Some(_) => {
                return Err(format!(
                    "{}: warm-start snapshot set differs from {} — a warm shard \
                     cannot be merged with a cold one (their cells did not see the \
                     same memory); refusing to merge",
                    dir.display(),
                    inputs[0].display()
                ));
            }
        }
    }

    // The authoritative merged store: fold of the unioned cells'
    // observations (exact sums make the order irrelevant). Deduplicated
    // cells contribute once, which is why this — not the per-shard fold —
    // is what gets written.
    let mut store = SkillStore::new();
    for (_, (_, result)) in &merged {
        store.merge(&result.skill_obs);
    }
    // Cross-check: with disjoint shards (nothing deduplicated), folding the
    // per-shard stores reproduces the cell-derived store bit for bit. A
    // mismatch is the same crash class as a torn tail — a shard killed
    // between a results append and its store save lags by one cell — so it
    // is tolerated with a warning; the cell-derived store is authoritative
    // either way (resuming the shard also reconciles its store).
    if deduplicated == 0 {
        if let Some(fold) = &folded_stores {
            if *fold != store {
                crate::log_warn!(
                    "per-shard skills.json stores lag their checkpoints (interrupted \
                     shard?); using the store rebuilt from the checkpointed cells"
                );
            }
        }
    }

    // Write the output dir: unsharded manifest, canonically-ordered
    // results.jsonl (atomic via tmp + rename), merged skill store.
    let mut manifest = base.expect("at least one input");
    manifest.shards = 1;
    manifest.shard_index = 0;
    out_rd
        .write_manifest(&manifest)
        .map_err(|e| format!("writing merged manifest: {e}"))?;
    let mut buf = String::new();
    for (_, (line, _)) in &merged {
        buf.push_str(line);
        buf.push('\n');
    }
    let results_path = out_rd.results_path();
    let tmp = results_path.with_extension("jsonl.tmp");
    std::fs::write(&tmp, buf).map_err(|e| format!("writing merged results: {e}"))?;
    std::fs::rename(&tmp, &results_path).map_err(|e| format!("writing merged results: {e}"))?;
    store
        .save(&out_rd.skills_path())
        .map_err(|e| format!("writing merged skill store: {e}"))?;
    for (name, bytes) in &snapshots {
        std::fs::write(out_rd.root().join(name), bytes)
            .map_err(|e| format!("writing merged snapshot {name}: {e}"))?;
    }

    // Coverage check: the manifests declare how many shards the matrix was
    // split into; missing indices mean a partial merge. Supported (the
    // output can be --resume'd to completion), but never silent.
    let declared = summaries.iter().map(|s| s.shards).max().unwrap_or(1);
    let missing_shards: Vec<usize> = (0..declared)
        .filter(|i| !summaries.iter().any(|s| s.shard_index == *i))
        .collect();
    if !missing_shards.is_empty() {
        crate::log_warn!(
            "merged {} input(s) but the manifests declare {declared} shard(s); \
             missing shard index(es) {missing_shards:?} — the output covers a \
             partial matrix",
            summaries.len()
        );
    }

    Ok(MergeReport {
        inputs: summaries,
        merged_cells: merged.len(),
        deduplicated,
        skill_observations: store.observations,
        missing_shards,
    })
}
