//! Layer-3 coordinator: Algorithm 1's closed loop (`loop_runner`) and the
//! suite-orchestration v2 engine — work-stealing scheduling (`scheduler`,
//! including epoch-based live memory exchange between shards), incremental
//! JSONL checkpointing + resume (`checkpoint`), sharded execution with
//! one-shot *and* streaming run-dir merging (`merge`), the shard process
//! launcher and cross-machine worker/fleet runtimes (`launcher`), and the
//! pluggable run-dir transports that move artifacts between machines
//! (`transport`), plus the suite/matrix entry points (`suite_runner`),
//! the typed job-identity protocol (`protocol`), and the long-lived
//! kernel-optimization-as-a-service daemon + client (`service`).
//!
//! The run-directory layout, the exchange protocol, the worker-manifest
//! format, the job-manifest format, and the byte-level merge determinism
//! contract are specified normatively in `docs/memory-formats.md`.

#![warn(missing_docs)]

pub mod checkpoint;
pub mod launcher;
pub mod loop_runner;
pub mod merge;
pub mod protocol;
pub mod scheduler;
pub mod service;
pub mod suite_runner;
pub mod transport;

pub use checkpoint::{CellKey, RunDir, RunManifest};
pub use launcher::{
    launch, launch_workers, run_worker, FleetConfig, FleetReport, LaunchConfig, LaunchReport,
    WorkerConfig, WorkerReport,
};
pub use loop_runner::{run_task, Branch, LoopConfig, RoundRecord, TaskResult};
pub use merge::{merge_run_dirs, MergeReport, MergeWatcher, WatchStatus};
pub use protocol::{JobSpec, JobState, Request, JOBSPEC_VERSION, MATRIX_COMMANDS, SHARDABLE};
pub use scheduler::{
    batch_bounds, exchange_windows, Batch, ExchangeOptions, ExchangeWaitTimeout, Shard,
    SuiteOptions, DEFAULT_EXCHANGE_EPOCH, EXCHANGE_TIMEOUT_EXIT, EXCHANGE_TIMEOUT_PREFIX,
};
pub use service::{serve, validate_service_dir, Client, ServiceConfig, JOB_MANIFEST_VERSION};
pub use suite_runner::{run_matrix, run_matrix_with, run_suite, run_suite_with, SuiteResult};
pub use transport::{
    claim_next_batch, expire_lease, lease_expired_name, lease_name, parse_lease_name,
    read_lease_board, BatchLeaseState, Lease, LocalFs, MirrorDir, RunDirTransport, TransportKind,
    TransportSpec, WorkerManifest, WorkerSpec, LEASES,
};
