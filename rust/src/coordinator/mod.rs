//! Layer-3 coordinator: Algorithm 1's closed loop (`loop_runner`) and the
//! parallel suite engine (`suite_runner`).

pub mod loop_runner;
pub mod suite_runner;

pub use loop_runner::{run_task, Branch, LoopConfig, RoundRecord, TaskResult};
pub use suite_runner::{run_matrix, run_suite, SuiteResult};
